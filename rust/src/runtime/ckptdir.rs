//! Checkpoint *directories* — the durable train→serve interchange format.
//!
//! A checkpoint dir holds everything needed to reload a run without the
//! original config:
//!
//! ```text
//! <dir>/
//!   meta.toml      model/recipe/seed/step/vocab + format version
//!   params.ckpt    named parameter tensors (CHONCKPT binary format)
//!   optim.ckpt     Adam m/v tensors + step (optional for inference)
//!   tokenizer.txt  the tokenizer vocab (byte level or learned merges)
//! ```
//!
//! Loading validates the metadata against the named model/recipe tables
//! and every tensor name + shape against the model's parameter layout,
//! so a mismatched or corrupted checkpoint fails loudly instead of
//! producing garbage generations.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::toml;
use crate::data::tokenizer::Tokenizer;
use crate::runtime::tensor::{load_checkpoint, save_checkpoint, HostTensor};

/// Bumped on incompatible layout changes.
pub const FORMAT_VERSION: usize = 1;

pub const META_FILE: &str = "meta.toml";
pub const PARAMS_FILE: &str = "params.ckpt";
pub const OPTIM_FILE: &str = "optim.ckpt";
pub const TOKENIZER_FILE: &str = "tokenizer.txt";

/// The identity of a checkpoint (meta.toml contents).
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub format_version: usize,
    pub model: String,
    pub recipe: String,
    pub seed: u64,
    pub step: usize,
    pub vocab: usize,
    /// how many batches the data pipeline had emitted when this
    /// checkpoint was written (training steps + diag/eval probes).
    /// `Trainer::restore` fast-forwards the stream past them so a
    /// resumed run sees exactly the batches an uninterrupted run would.
    /// Optional on read (0 for pre-v2 checkpoints: legacy behavior,
    /// stream restarts from its head).
    pub data_batches: u64,
    /// monotonic *publication* stamp: every `Trainer` save into a
    /// checkpoint parent writes `max(existing generations) + 1` (see
    /// `next_generation`), so a serving registry can detect a republished
    /// checkpoint — even at the same step — by comparing generations and
    /// hot-reload the model. Optional on read (0 for older checkpoints).
    pub generation: u64,
}

impl CheckpointMeta {
    fn to_toml(&self) -> String {
        format!(
            "# chon checkpoint metadata (written by Trainer::save_checkpoint_to)\n\
             format_version = {}\nmodel = \"{}\"\nrecipe = \"{}\"\n\
             seed = {}\nstep = {}\nvocab = {}\ndata_batches = {}\n\
             generation = {}\n",
            self.format_version, self.model, self.recipe, self.seed, self.step,
            self.vocab, self.data_batches, self.generation
        )
    }

    fn from_toml(text: &str) -> Result<CheckpointMeta> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let need_str = |key: &str| -> Result<String> {
            let v = doc.str_or("", key, "");
            if v.is_empty() {
                bail!("checkpoint meta missing {key:?}");
            }
            Ok(v.to_string())
        };
        let need_int = |key: &str| -> Result<i64> {
            if doc.get("", key).is_none() {
                bail!("checkpoint meta missing {key:?}");
            }
            Ok(doc.int_or("", key, 0))
        };
        // optional: older checkpoints predate the stream position. A
        // negative value (corruption / hand edit) must not wrap to ~2^64
        // — restore() fast-forwards the stream this many batches.
        let data_batches = doc.int_or("", "data_batches", 0);
        if data_batches < 0 {
            bail!("checkpoint meta has negative data_batches {data_batches}");
        }
        // optional for the same reason: pre-registry checkpoints carry no
        // publication stamp and read as generation 0
        let generation = doc.int_or("", "generation", 0);
        if generation < 0 {
            bail!("checkpoint meta has negative generation {generation}");
        }
        Ok(CheckpointMeta {
            format_version: need_int("format_version")? as usize,
            model: need_str("model")?,
            recipe: need_str("recipe")?,
            seed: need_int("seed")? as u64,
            step: need_int("step")? as usize,
            vocab: need_int("vocab")? as usize,
            data_batches: data_batches as u64,
            generation: generation as u64,
        })
    }
}

/// Optimizer state as stored in optim.ckpt.
pub struct OptimState {
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: usize,
}

/// Everything a checkpoint dir contains.
pub struct LoadedCheckpoint {
    pub meta: CheckpointMeta,
    /// (name, tensor) pairs in parameter-slot order
    pub params: Vec<(String, HostTensor)>,
    /// absent when optim.ckpt is missing (inference-only copies)
    pub optim: Option<OptimState>,
    pub tokenizer: Tokenizer,
}

/// Atomically replace `dir/<name>` by writing `dir/<name>.tmp` first and
/// renaming it into place (same-directory rename: atomic on POSIX). A
/// concurrent reader sees either the complete old file or the complete
/// new one, never a truncated in-progress write.
fn publish_file(dir: &Path, name: &str, write: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    write(&tmp)?;
    std::fs::rename(&tmp, dir.join(name))
        .with_context(|| format!("publishing {name} into {}", dir.display()))?;
    Ok(())
}

/// Write a complete checkpoint directory (params + optimizer + tokenizer
/// + metadata). `dir` is created; existing files are overwritten.
///
/// Every file lands via tmp-file + atomic rename, and `meta.toml` is
/// written LAST: its presence — and its `generation` stamp — is what
/// publishes a checkpoint to `resolve` and to a live serving registry's
/// hot-reload probe. A brand-new step directory is invisible until it is
/// complete, and a same-step republish never exposes a truncated tensor
/// file to a concurrent `Engine::load` — the worst case mid-republish is
/// new weights briefly read under the old generation stamp, which the
/// next probe corrects (the weights themselves are never torn).
pub fn save_dir(
    dir: &Path,
    meta: &CheckpointMeta,
    params: &[(String, HostTensor)],
    optim: Option<(&[HostTensor], &[HostTensor], usize)>,
    tokenizer: &Tokenizer,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    publish_file(dir, TOKENIZER_FILE, |p| {
        std::fs::write(p, tokenizer.to_text()).map_err(Into::into)
    })?;
    publish_file(dir, PARAMS_FILE, |p| save_checkpoint(p, params))?;
    if let Some((m, v, step)) = optim {
        let mut tensors: Vec<(String, HostTensor)> = Vec::new();
        for (i, t) in m.iter().enumerate() {
            tensors.push((format!("m[{i}]"), t.clone()));
        }
        for (i, t) in v.iter().enumerate() {
            tensors.push((format!("v[{i}]"), t.clone()));
        }
        tensors.push(("step".into(), HostTensor::scalar_i32(step as i32)));
        publish_file(dir, OPTIM_FILE, |p| save_checkpoint(p, &tensors))?;
    }
    publish_file(dir, META_FILE, |p| {
        std::fs::write(p, meta.to_toml()).map_err(Into::into)
    })?;
    Ok(())
}

/// Read and validate just the metadata of a checkpoint dir (cheap probe
/// used to decide which model/recipe tables to validate against).
pub fn load_meta(dir: &Path) -> Result<CheckpointMeta> {
    let meta_path = dir.join(META_FILE);
    let meta_text = std::fs::read_to_string(&meta_path).with_context(|| {
        format!(
            "{} is not a checkpoint dir (missing {META_FILE})",
            dir.display()
        )
    })?;
    let meta = CheckpointMeta::from_toml(&meta_text)
        .with_context(|| format!("parsing {}", meta_path.display()))?;
    if meta.format_version != FORMAT_VERSION {
        bail!(
            "checkpoint {} has format_version {} (this build reads {})",
            dir.display(),
            meta.format_version,
            FORMAT_VERSION
        );
    }
    Ok(meta)
}

/// Load and validate a checkpoint directory.
///
/// `expect_specs` is the (name, shape) layout the caller's model demands;
/// any mismatch (count, name or shape) is a hard error naming the first
/// offending tensor.
pub fn load_dir(
    dir: &Path,
    expect_specs: &[(String, Vec<usize>)],
) -> Result<LoadedCheckpoint> {
    let meta = load_meta(dir)?;

    let tok_path = dir.join(TOKENIZER_FILE);
    let tok_text = std::fs::read_to_string(&tok_path)
        .with_context(|| format!("reading {}", tok_path.display()))?;
    let tokenizer = Tokenizer::from_text(&tok_text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", tok_path.display()))?;

    let params = load_checkpoint(&dir.join(PARAMS_FILE))
        .with_context(|| format!("reading params of {}", dir.display()))?;
    if params.len() != expect_specs.len() {
        bail!(
            "checkpoint {} has {} parameter tensors, model {} expects {}",
            dir.display(),
            params.len(),
            meta.model,
            expect_specs.len()
        );
    }
    for ((name, t), (want_name, want_shape)) in params.iter().zip(expect_specs) {
        if name != want_name {
            bail!(
                "checkpoint tensor {name:?} does not match expected slot \
                 {want_name:?} (model mismatch?)"
            );
        }
        if &t.shape != want_shape {
            bail!(
                "checkpoint tensor {name} has shape {:?}, model {} expects {:?}",
                t.shape,
                meta.model,
                want_shape
            );
        }
    }

    let optim_path = dir.join(OPTIM_FILE);
    let optim = if optim_path.exists() {
        let tensors = load_checkpoint(&optim_path)
            .with_context(|| format!("reading optimizer state of {}", dir.display()))?;
        let k = expect_specs.len();
        if tensors.len() != 2 * k + 1 {
            bail!(
                "optimizer state has {} tensors, expected {} (2k + step)",
                tensors.len(),
                2 * k + 1
            );
        }
        let m: Vec<HostTensor> = tensors[..k].iter().map(|(_, t)| t.clone()).collect();
        let v: Vec<HostTensor> =
            tensors[k..2 * k].iter().map(|(_, t)| t.clone()).collect();
        let (ref sname, ref stensor) = tensors[2 * k];
        if sname != "step" {
            bail!("optimizer state missing the step scalar");
        }
        Some(OptimState { m, v, step: stensor.i32_data[0] as usize })
    } else {
        None
    };

    Ok(LoadedCheckpoint { meta, params, optim, tokenizer })
}

/// Resolve a user-supplied path to one checkpoint dir: either the dir
/// itself (contains meta.toml) or a parent holding several checkpoints,
/// in which case the one with the highest step wins — ties broken by
/// directory name, so the choice never depends on read_dir order.
pub fn resolve(path: &Path) -> Result<PathBuf> {
    if path.join(META_FILE).exists() {
        return Ok(path.to_path_buf());
    }
    let rd = std::fs::read_dir(path)
        .with_context(|| format!("reading checkpoint dir {}", path.display()))?;
    let mut best: Option<(usize, PathBuf)> = None;
    for e in rd.flatten() {
        let sub = e.path();
        let meta_path = sub.join(META_FILE);
        if !meta_path.exists() {
            continue;
        }
        let step = std::fs::read_to_string(&meta_path)
            .ok()
            .and_then(|t| CheckpointMeta::from_toml(&t).ok())
            .map(|m| m.step)
            .unwrap_or(0);
        let better = match &best {
            None => true,
            Some((s, p)) => step > *s || (step == *s && sub > *p),
        };
        if better {
            best = Some((step, sub));
        }
    }
    match best {
        Some((_, dir)) => Ok(dir),
        None => bail!(
            "{} contains no checkpoint (no {META_FILE} in it or any subdirectory)",
            path.display()
        ),
    }
}

/// The publication stamp the *next* save into `parent` must carry: one
/// past the highest generation of any checkpoint already under `parent`
/// (the dir itself or an immediate subdirectory — the same set `resolve`
/// scans). Scanning the disk instead of keeping an in-process counter
/// makes the stamp monotonic across separate `chon train` invocations
/// republishing into the same directory, which is the train→serve
/// continuous-deployment contract. Unreadable metas count as 0 rather
/// than failing — a save must not be blocked by one corrupt sibling.
pub fn next_generation(parent: &Path) -> u64 {
    let gen_of = |dir: &Path| -> u64 {
        std::fs::read_to_string(dir.join(META_FILE))
            .ok()
            .and_then(|t| CheckpointMeta::from_toml(&t).ok())
            .map(|m| m.generation)
            .unwrap_or(0)
    };
    let mut best = gen_of(parent);
    if let Ok(rd) = std::fs::read_dir(parent) {
        for e in rd.flatten() {
            best = best.max(gen_of(&e.path()));
        }
    }
    best + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("chon_ckptdir_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn demo_params() -> Vec<(String, HostTensor)> {
        vec![
            ("params['a']".into(), HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.])),
            ("params['b']".into(), HostTensor::f32(vec![3], vec![5., 6., 7.])),
        ]
    }

    fn demo_meta() -> CheckpointMeta {
        CheckpointMeta {
            format_version: FORMAT_VERSION,
            model: "tiny_gla".into(),
            recipe: "chon".into(),
            seed: 3,
            step: 20,
            vocab: 256,
            data_batches: 22,
            generation: 4,
        }
    }

    fn specs_of(params: &[(String, HostTensor)]) -> Vec<(String, Vec<usize>)> {
        params.iter().map(|(n, t)| (n.clone(), t.shape.clone())).collect()
    }

    #[test]
    fn save_load_roundtrip_with_optimizer() {
        let dir = tmpdir("roundtrip");
        let params = demo_params();
        let m: Vec<HostTensor> = params.iter().map(|(_, t)| t.clone()).collect();
        let v = m.clone();
        save_dir(
            &dir,
            &demo_meta(),
            &params,
            Some((m.as_slice(), v.as_slice(), 20)),
            &Tokenizer::byte_level(),
        )
        .unwrap();
        let back = load_dir(&dir, &specs_of(&params)).unwrap();
        assert_eq!(back.meta, demo_meta());
        assert_eq!(back.params[0].1.f32_data, params[0].1.f32_data);
        let optim = back.optim.unwrap();
        assert_eq!(optim.step, 20);
        assert_eq!(optim.m.len(), 2);
        assert_eq!(back.tokenizer.vocab, 256);
        // resolve() accepts both the dir and its parent
        assert_eq!(resolve(&dir).unwrap(), dir);
    }

    #[test]
    fn legacy_meta_without_data_batches_loads() {
        let dir = tmpdir("legacy_meta");
        let mut meta = demo_meta();
        meta.data_batches = 0;
        let text = meta.to_toml().replace("data_batches = 0\n", "");
        assert!(!text.contains("data_batches"));
        std::fs::write(dir.join(META_FILE), text).unwrap();
        let back = load_meta(&dir).unwrap();
        assert_eq!(back, meta, "missing data_batches must default to 0");
    }

    #[test]
    fn legacy_meta_without_generation_loads_as_zero() {
        let dir = tmpdir("legacy_gen");
        let mut meta = demo_meta();
        meta.generation = 0;
        let text = meta.to_toml().replace("generation = 0\n", "");
        assert!(!text.contains("generation"));
        std::fs::write(dir.join(META_FILE), text).unwrap();
        let back = load_meta(&dir).unwrap();
        assert_eq!(back, meta, "missing generation must default to 0");
        let neg = meta.to_toml().replace("generation = 0", "generation = -2");
        std::fs::write(dir.join(META_FILE), neg).unwrap();
        assert!(load_meta(&dir).is_err(), "negative generation must fail");
    }

    #[test]
    fn next_generation_scans_parent_and_children() {
        let parent = tmpdir("nextgen");
        assert_eq!(next_generation(&parent), 1, "empty dir starts at 1");
        let params = demo_params();
        for (step, generation) in [(10usize, 1u64), (20, 5), (30, 3)] {
            let mut meta = demo_meta();
            meta.step = step;
            meta.generation = generation;
            let d = parent.join(format!("ck_{step:05}"));
            save_dir(&d, &meta, &params, None, &Tokenizer::byte_level()).unwrap();
        }
        assert_eq!(next_generation(&parent), 6, "max child generation + 1");
        // a checkpoint directly at the parent counts too
        let mut meta = demo_meta();
        meta.generation = 9;
        std::fs::write(parent.join(META_FILE), meta.to_toml()).unwrap();
        assert_eq!(next_generation(&parent), 10);
    }

    #[test]
    fn resolve_picks_highest_step() {
        let parent = tmpdir("resolve");
        let params = demo_params();
        for step in [10usize, 30, 20] {
            let mut meta = demo_meta();
            meta.step = step;
            let d = parent.join(format!("ck_{step:05}"));
            save_dir(&d, &meta, &params, None, &Tokenizer::byte_level()).unwrap();
        }
        let got = resolve(&parent).unwrap();
        assert!(got.ends_with("ck_00030"), "{}", got.display());
        assert!(resolve(&tmpdir("resolve_empty")).is_err());
    }

    #[test]
    fn shape_and_name_mismatches_rejected() {
        let dir = tmpdir("mismatch");
        let params = demo_params();
        save_dir(&dir, &demo_meta(), &params, None, &Tokenizer::byte_level()).unwrap();

        let mut wrong_shape = specs_of(&params);
        wrong_shape[1].1 = vec![4];
        let err = load_dir(&dir, &wrong_shape).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");

        let mut wrong_name = specs_of(&params);
        wrong_name[0].0 = "params['z']".into();
        let err = load_dir(&dir, &wrong_name).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");

        let short = &specs_of(&params)[..1];
        let err = load_dir(&dir, short).unwrap_err().to_string();
        assert!(err.contains("parameter tensors"), "{err}");
    }

    #[test]
    fn corrupt_files_fail_loudly() {
        let dir = tmpdir("corrupt");
        let params = demo_params();
        save_dir(&dir, &demo_meta(), &params, None, &Tokenizer::byte_level()).unwrap();
        // truncate params.ckpt mid-tensor
        let p = dir.join(PARAMS_FILE);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_dir(&dir, &specs_of(&params)).is_err());
        // garbage magic
        std::fs::write(&p, b"NOTACKPT").unwrap();
        assert!(load_dir(&dir, &specs_of(&params)).is_err());
        // missing meta entirely
        std::fs::remove_file(dir.join(META_FILE)).unwrap();
        let err = load_dir(&dir, &specs_of(&params)).unwrap_err().to_string();
        assert!(err.contains("not a checkpoint dir"), "{err}");
    }
}
