//! The native pure-Rust execution engine.
//!
//! Resolves the same artifact names the AOT pipeline emits
//! (`init_<model>`, `train_<model>_<recipe>`, `eval_…`, `diag_…`,
//! `fwd_<model>`) but synthesizes the manifest and executes the training
//! step directly on the util::ndarray + quant + hcp substrates — no
//! artifacts directory, no libxla, fully offline and deterministic.

pub mod model;
pub mod recipe;
pub mod shard;

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::artifact::{Manifest, Slot};
use crate::runtime::backend::{check_inputs, Backend, Executable};
use crate::runtime::tensor::{DType, HostTensor};

pub use model::{model_cfg, Arch, ModelCfg, ParamSpec};
pub use recipe::{available_recipes, NativeRecipe};
pub use shard::ShardExec;

/// The models the native engine ships.
pub fn available_models() -> Vec<&'static str> {
    vec!["tiny_gla", "tiny_sa"]
}

/// Tab. 3 operator list for a model name.
pub fn sensitivity_ops_for(model: &str) -> Result<Vec<String>> {
    Ok(recipe::sensitivity_ops(model_cfg(model)?.arch))
}

/// Artifact kinds the engine understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Kind {
    Init,
    Train,
    Eval,
    Diag,
    Fwd,
}

/// Split an artifact name into (kind, model, recipe).
pub(crate) fn parse_name(name: &str) -> Result<(Kind, String, Option<String>)> {
    let cases: [(&str, Kind, bool); 5] = [
        ("init_", Kind::Init, false),
        ("train_", Kind::Train, true),
        ("eval_", Kind::Eval, true),
        ("diag_", Kind::Diag, true),
        ("fwd_", Kind::Fwd, false),
    ];
    for (prefix, kind, has_recipe) in cases {
        if let Some(rest) = name.strip_prefix(prefix) {
            if !has_recipe {
                return Ok((kind, rest.to_string(), None));
            }
            for m in available_models() {
                if let Some(r) = rest.strip_prefix(&format!("{m}_")) {
                    return Ok((kind, m.to_string(), Some(r.to_string())));
                }
            }
            bail!("cannot split model/recipe in artifact name {name:?}");
        }
    }
    bail!("unknown artifact name {name:?}");
}

fn slot(index: usize, name: &str, dtype: DType, shape: Vec<usize>) -> Slot {
    Slot { index, name: name.to_string(), dtype, shape }
}

fn base_meta(cfg: &ModelCfg, kind: &str, recipe_name: Option<&str>) -> BTreeMap<String, String> {
    let mut meta = BTreeMap::new();
    meta.insert("kind".into(), kind.into());
    meta.insert("backend".into(), "native".into());
    meta.insert("model".into(), cfg.name.clone());
    if let Some(r) = recipe_name {
        meta.insert("recipe".into(), r.into());
    }
    meta.insert("vocab".into(), cfg.vocab.to_string());
    meta.insert("batch".into(), cfg.batch.to_string());
    meta.insert("seq_len".into(), cfg.seq.to_string());
    meta.insert("total_steps".into(), cfg.total_steps.to_string());
    meta
}

pub(crate) fn build_manifest(
    name: &str,
    kind: Kind,
    cfg: &ModelCfg,
    recipe_name: Option<&str>,
) -> Manifest {
    let specs = model::param_specs(cfg);
    let (b, s) = (cfg.batch, cfg.seq);
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    let mut metrics = Vec::new();
    let push_params = |dst: &mut Vec<Slot>| {
        for spec in &specs {
            let idx = dst.len();
            dst.push(slot(idx, &spec.name, DType::F32, spec.shape.clone()));
        }
    };
    match kind {
        Kind::Init => {
            inputs.push(slot(0, "seed", DType::I32, vec![]));
            for spec in &specs {
                let idx = outputs.len();
                outputs.push(slot(idx, &spec.name, DType::F32, spec.shape.clone()));
            }
        }
        Kind::Train => {
            push_params(&mut inputs);
            let k = specs.len();
            for (i, spec) in specs.iter().enumerate() {
                inputs.push(slot(k + i, &format!("m[{i}]"), DType::F32, spec.shape.clone()));
            }
            for (i, spec) in specs.iter().enumerate() {
                inputs
                    .push(slot(2 * k + i, &format!("v[{i}]"), DType::F32, spec.shape.clone()));
            }
            inputs.push(slot(3 * k, "step", DType::I32, vec![]));
            inputs.push(slot(3 * k + 1, "tokens", DType::I32, vec![b, s]));
            inputs.push(slot(3 * k + 2, "targets", DType::I32, vec![b, s]));
            inputs.push(slot(3 * k + 3, "seed", DType::I32, vec![]));
            for (i, spec) in specs.iter().enumerate() {
                let suffix = spec.name.strip_prefix("params").unwrap_or(&spec.name);
                outputs.push(slot(i, &format!("out{suffix}"), DType::F32, spec.shape.clone()));
            }
            for (i, spec) in specs.iter().enumerate() {
                outputs
                    .push(slot(k + i, &format!("out_m[{i}]"), DType::F32, spec.shape.clone()));
            }
            for (i, spec) in specs.iter().enumerate() {
                outputs.push(slot(
                    2 * k + i,
                    &format!("out_v[{i}]"),
                    DType::F32,
                    spec.shape.clone(),
                ));
            }
            outputs.push(slot(3 * k, "loss", DType::F32, vec![]));
            outputs.push(slot(3 * k + 1, "grad_norm", DType::F32, vec![]));
            outputs.push(slot(3 * k + 2, "lr", DType::F32, vec![]));
        }
        Kind::Eval => {
            push_params(&mut inputs);
            let k = specs.len();
            inputs.push(slot(k, "tokens", DType::I32, vec![b, s]));
            inputs.push(slot(k + 1, "targets", DType::I32, vec![b, s]));
            outputs.push(slot(0, "loss", DType::F32, vec![]));
            outputs.push(slot(1, "accuracy", DType::F32, vec![]));
        }
        Kind::Fwd => {
            push_params(&mut inputs);
            let k = specs.len();
            inputs.push(slot(k, "tokens", DType::I32, vec![b, s]));
            outputs.push(slot(0, "logits", DType::F32, vec![b, s, cfg.vocab]));
        }
        Kind::Diag => {
            push_params(&mut inputs);
            let k = specs.len();
            inputs.push(slot(k, "tokens", DType::I32, vec![b, s]));
            inputs.push(slot(k + 1, "step", DType::I32, vec![]));
            metrics = model::metric_names(cfg);
            outputs.push(slot(0, "metrics", DType::F32, vec![metrics.len()]));
            for (i, (tag, chans)) in model::diag_map_shapes(cfg).iter().enumerate() {
                outputs.push(slot(1 + i, tag, DType::F32, vec![cfg.layers, *chans]));
            }
        }
    }
    Manifest {
        name: name.to_string(),
        meta: base_meta(
            cfg,
            match kind {
                Kind::Init => "init",
                Kind::Train => "train",
                Kind::Eval => "eval",
                Kind::Diag => "diag",
                Kind::Fwd => "fwd",
            },
            recipe_name,
        ),
        inputs,
        outputs,
        metrics,
    }
}

/// One resolved native artifact.
pub struct NativeExec {
    kind: Kind,
    cfg: ModelCfg,
    recipe: Option<NativeRecipe>,
    manifest: Manifest,
    /// Train artifacts delegate to the shard engine at shards = 1, so the
    /// raw `Backend::load` path produces the exact bits `chon train`
    /// does (one per-sequence grad decomposition, not two divergent
    /// train-step implementations). `model::train_step` stays as the
    /// fused reference for its own unit tests.
    train_impl: Option<shard::ShardExec>,
}

impl NativeExec {
    pub fn new(name: &str) -> Result<NativeExec> {
        let (kind, model_name, recipe_name) = parse_name(name)?;
        let cfg = model_cfg(&model_name)?;
        let rec = match &recipe_name {
            Some(r) => Some(recipe::recipe(r)?),
            None => None,
        };
        let manifest = build_manifest(name, kind, &cfg, recipe_name.as_deref());
        let train_impl = match kind {
            Kind::Train => Some(shard::ShardExec::new(name, 1)?),
            _ => None,
        };
        Ok(NativeExec { kind, cfg, recipe: rec, manifest, train_impl })
    }

    fn bf16(&self) -> NativeRecipe {
        recipe::recipe("bf16").expect("bf16 recipe")
    }
}

impl Executable for NativeExec {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        check_inputs(&self.manifest, inputs)?;
        let k = model::param_specs(&self.cfg).len();
        match self.kind {
            Kind::Init => {
                let seed = inputs[0].i32_data[0] as u64;
                Ok(model::init_params(&self.cfg, seed))
            }
            Kind::Train => self
                .train_impl
                .as_ref()
                .expect("train artifact built its shard impl")
                .run(inputs),
            Kind::Eval => {
                let rec = self.recipe.clone().unwrap_or_else(|| self.bf16());
                let (loss, acc) = model::eval_step(
                    &self.cfg,
                    &rec,
                    &inputs[..k],
                    &inputs[k].i32_data,
                    &inputs[k + 1].i32_data,
                );
                Ok(vec![HostTensor::scalar_f32(loss), HostTensor::scalar_f32(acc)])
            }
            Kind::Fwd => {
                let rec = self.bf16(); // forward scoring runs full precision
                let logits = model::forward_logits(
                    &self.cfg,
                    &rec,
                    &inputs[..k],
                    &inputs[k].i32_data,
                );
                Ok(vec![HostTensor::f32(
                    vec![self.cfg.batch, self.cfg.seq, self.cfg.vocab],
                    logits.data,
                )])
            }
            Kind::Diag => {
                let rec = self.recipe.clone().unwrap_or_else(|| self.bf16());
                let (values, maps) = model::diag_step(
                    &self.cfg,
                    &rec,
                    &inputs[..k],
                    &inputs[k].i32_data,
                );
                let mut out =
                    vec![HostTensor::f32(vec![values.len()], values)];
                for map in maps {
                    out.push(HostTensor::f32(vec![map.rows, map.cols], map.data));
                }
                Ok(out)
            }
        }
    }
}

/// The native engine (stateless: executables are cheap to construct).
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self, _dir: &Path, name: &str) -> Result<Manifest> {
        let (kind, model_name, recipe_name) = parse_name(name)?;
        let cfg = model_cfg(&model_name)?;
        if let Some(r) = &recipe_name {
            recipe::recipe(r)?; // validate
        }
        Ok(build_manifest(name, kind, &cfg, recipe_name.as_deref()))
    }

    fn load(&self, _dir: &Path, name: &str) -> Result<Rc<dyn Executable>> {
        Ok(Rc::new(NativeExec::new(name)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_names() {
        let (k, m, r) = parse_name("train_tiny_gla_chon_no_sr").unwrap();
        assert_eq!(k, Kind::Train);
        assert_eq!(m, "tiny_gla");
        assert_eq!(r.as_deref(), Some("chon_no_sr"));
        let (k, m, r) = parse_name("init_tiny_sa").unwrap();
        assert_eq!(k, Kind::Init);
        assert_eq!(m, "tiny_sa");
        assert!(r.is_none());
        assert!(parse_name("bogus_tiny_gla").is_err());
        assert!(parse_name("train_big_model_chon").is_err());
    }

    #[test]
    fn train_manifest_shape_matches_trainer_protocol() {
        let man = NativeBackend
            .manifest(Path::new("unused"), "train_tiny_gla_chon")
            .unwrap();
        let k = man.inputs_with_prefix("params").len();
        assert!(k > 0);
        // 3k state inputs + step + tokens + targets + seed
        assert_eq!(man.inputs.len(), 3 * k + 4);
        // 3k state outputs + loss + grad_norm + lr
        assert_eq!(man.outputs.len(), 3 * k + 3);
        assert_eq!(man.meta_usize("vocab").unwrap(), 256);
        assert_eq!(man.meta_usize("batch").unwrap(), 4);
        assert_eq!(man.meta_usize("seq_len").unwrap(), 32);
        assert!(man.meta_usize("total_steps").unwrap() > 0);
        // ablation's param counting sees the per-op weight names
        assert!(man.inputs.iter().any(|s| s.name.contains("['wq']")));
        assert!(man.inputs.iter().any(|s| s.name.contains("['wgk']")));
    }

    #[test]
    fn init_then_train_roundtrip() {
        let be = NativeBackend;
        let dir = Path::new("unused");
        let init = be.load(dir, "init_tiny_gla").unwrap();
        let params = init.run(&[HostTensor::scalar_i32(3)]).unwrap();
        let train = be.load(dir, "train_tiny_gla_bf16").unwrap();
        let man = train.manifest();
        let k = params.len();
        let mut inputs = params.clone();
        for p in &params {
            inputs.push(HostTensor::zeros(p.dtype, p.shape.clone()));
        }
        for p in &params {
            inputs.push(HostTensor::zeros(p.dtype, p.shape.clone()));
        }
        inputs.push(HostTensor::scalar_i32(0));
        let (b, s) = (4, 32);
        inputs.push(HostTensor::i32(vec![b, s], vec![65; b * s]));
        inputs.push(HostTensor::i32(vec![b, s], vec![66; b * s]));
        inputs.push(HostTensor::scalar_i32(3));
        let out = train.run(&inputs).unwrap();
        assert_eq!(out.len(), man.outputs.len());
        let loss = out[3 * k].f32_data[0];
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn run_rejects_bad_inputs() {
        let be = NativeBackend;
        let init = be.load(Path::new("x"), "init_tiny_gla").unwrap();
        let err = init.run(&[]).unwrap_err().to_string();
        assert!(err.contains("inputs"), "{err}");
    }

    #[test]
    fn diag_manifest_metrics_nonempty() {
        let man = NativeBackend
            .manifest(Path::new("x"), "diag_tiny_gla_chon")
            .unwrap();
        assert!(!man.metrics.is_empty());
        assert!(man.metrics.iter().any(|n| n == "L0.attn.gk.act.kurt"));
        assert_eq!(man.outputs.len(), 4); // metrics + 3 channel maps
        let man = NativeBackend
            .manifest(Path::new("x"), "diag_tiny_sa_bf16")
            .unwrap();
        assert_eq!(man.outputs.len(), 3); // metrics + 2 channel maps
    }

    #[test]
    fn unknown_recipe_rejected_at_load() {
        let be = NativeBackend;
        assert!(be.load(Path::new("x"), "train_tiny_gla_fp3").is_err());
    }
}
