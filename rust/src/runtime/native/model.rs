//! The native training step: a tiny GLA / softmax-attention LM with
//! hand-written backprop, Adam, and the NVFP4/CHON fake-quant recipe,
//! entirely on the util::ndarray + quant + hcp substrates.
//!
//! Architecture (both models): embed -> L x [rmsnorm -> attention ->
//! residual -> rmsnorm -> SwiGLU -> residual] -> rmsnorm -> lm_head.
//! GLA attention is the parallel-form gated linear attention: K is
//! modulated per-channel by sigmoid(X W_gk), scores are causal-masked and
//! row-normalized by 1/((t+1) sqrt(d)) (no softmax), and the context is
//! gated by sigmoid(X W_g) before W_o. SA is standard causal softmax.
//!
//! Quantization follows the recipe resolution of native::recipe: forward
//! GEMM operands are fake-quantized (NVFP4 1x16 activations, 2D 16x16
//! weights, optional HCP O2-B compensation); the Wgrad GEMM quantizes both
//! operands with optional RHT rotation over the contraction dim and
//! stochastic rounding on the gradient side. Gradients flow through the
//! quantizers with the straight-through estimator. Everything is
//! deterministic in (seed, step) — SR draws come from a per-step PRNG.

use anyhow::{bail, Result};

use crate::diagnostics;
use crate::hcp;
use crate::quant::{fp8_fake_quant, nvfp4, rht};
use crate::runtime::native::recipe::{op_quant, NativeRecipe, OpQuant, QuantKind};
use crate::runtime::tensor::HostTensor;
use crate::util::ndarray::{
    matmul, matmul_into, matmul_packed, matmul_quant_packed, Mat, PackedMat,
};
use crate::util::prng::Rng;

/// Attention family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Gla,
    Sa,
}

/// Static model configuration (the native analogue of the AOT meta).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub arch: Arch,
    pub vocab: usize,
    pub d: usize,
    pub ff: usize,
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    pub total_steps: usize,
}

/// Resolve a model config by name.
pub fn model_cfg(name: &str) -> Result<ModelCfg> {
    let arch = match name {
        "tiny_gla" => Arch::Gla,
        "tiny_sa" => Arch::Sa,
        other => bail!("unknown native model {other:?} (expected tiny_gla|tiny_sa)"),
    };
    Ok(ModelCfg {
        name: name.to_string(),
        arch,
        vocab: 256,
        d: 32,
        ff: 64,
        layers: 2,
        batch: 4,
        seq: 32,
        total_steps: 200,
    })
}

/// Per-layer weight slots, in parameter order.
pub(crate) fn layer_slots(arch: Arch) -> &'static [&'static str] {
    match arch {
        Arch::Gla => &[
            "attn_norm", "wq", "wk", "wv", "wgk", "wg", "wo", "mlp_norm",
            "w_up", "w_gate", "w_down",
        ],
        Arch::Sa => &[
            "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_up", "w_gate",
            "w_down",
        ],
    }
}

/// One named parameter slot.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

fn slot_shape(cfg: &ModelCfg, slot: &str) -> Vec<usize> {
    let (d, ff) = (cfg.d, cfg.ff);
    match slot {
        "attn_norm" | "mlp_norm" => vec![d],
        "w_up" | "w_gate" => vec![d, ff],
        "w_down" => vec![ff, d],
        _ => vec![d, d], // wq wk wv wgk wg wo
    }
}

/// The full parameter layout, in slot order.
pub fn param_specs(cfg: &ModelCfg) -> Vec<ParamSpec> {
    let mut out = vec![ParamSpec {
        name: "params['embed']".into(),
        shape: vec![cfg.vocab, cfg.d],
    }];
    for l in 0..cfg.layers {
        for slot in layer_slots(cfg.arch) {
            out.push(ParamSpec {
                name: format!("params['L{l}']['{slot}']"),
                shape: slot_shape(cfg, slot),
            });
        }
    }
    out.push(ParamSpec { name: "params['final_norm']".into(), shape: vec![cfg.d] });
    out.push(ParamSpec {
        name: "params['lm_head']".into(),
        shape: vec![cfg.d, cfg.vocab],
    });
    out
}

/// Index of a per-layer slot in the parameter list.
pub(crate) fn pidx(cfg: &ModelCfg, layer: usize, slot: &str) -> usize {
    let slots = layer_slots(cfg.arch);
    let off = slots
        .iter()
        .position(|s| *s == slot)
        .unwrap_or_else(|| panic!("no slot {slot} for {:?}", cfg.arch));
    1 + layer * slots.len() + off
}

pub(crate) fn final_norm_idx(cfg: &ModelCfg) -> usize {
    1 + cfg.layers * layer_slots(cfg.arch).len()
}

pub(crate) fn lm_head_idx(cfg: &ModelCfg) -> usize {
    final_norm_idx(cfg) + 1
}

/// Deterministic, seed-sensitive initialization.
pub fn init_params(cfg: &ModelCfg, seed: u64) -> Vec<HostTensor> {
    let base = Rng::new(seed ^ 0xC407_1A17);
    param_specs(cfg)
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let n: usize = spec.shape.iter().product();
            let data = if spec.name.contains("norm") {
                vec![1.0f32; n]
            } else if spec.name.contains("lm_head") {
                // zero head: uniform logits at step 0, fast early descent
                vec![0.0f32; n]
            } else {
                let scale = if spec.name.contains("embed") {
                    0.02
                } else {
                    1.0 / (spec.shape[0] as f32).sqrt()
                };
                let mut rng = base.fold_in(i as u64 + 1);
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, scale);
                v
            };
            HostTensor::f32(spec.shape.clone(), data)
        })
        .collect()
}

// ------------------------------------------------------------------
// Tensor plumbing
// ------------------------------------------------------------------

pub(crate) fn to_mat(t: &HostTensor) -> Mat {
    match t.shape.len() {
        1 => Mat::from_vec(1, t.shape[0], t.f32_data.clone()),
        2 => Mat::from_vec(t.shape[0], t.shape[1], t.f32_data.clone()),
        _ => panic!("native params are rank 1/2, got {:?}", t.shape),
    }
}

pub(crate) fn params_to_mats(params: &[HostTensor]) -> Vec<Mat> {
    params.iter().map(to_mat).collect()
}

fn mats_to_tensors(specs: &[ParamSpec], mats: Vec<Mat>) -> Vec<HostTensor> {
    specs
        .iter()
        .zip(mats)
        .map(|(s, m)| HostTensor::f32(s.shape.clone(), m.data))
        .collect()
}

fn rows_block(m: &Mat, start: usize, len: usize) -> Mat {
    Mat::from_vec(len, m.cols, m.data[start * m.cols..(start + len) * m.cols].to_vec())
}

fn set_rows_block(dst: &mut Mat, start: usize, src: &Mat) {
    let n = src.cols;
    dst.data[start * n..(start + src.rows) * n].copy_from_slice(&src.data);
}

fn map1(a: &Mat, f: impl Fn(f32) -> f32) -> Mat {
    Mat::from_vec(a.rows, a.cols, a.data.iter().map(|&x| f(x)).collect())
}

fn map2(a: &Mat, b: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
    Mat::from_vec(a.rows, a.cols, data)
}

fn map3(a: &Mat, b: &Mat, c: &Mat, f: impl Fn(f32, f32, f32) -> f32) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    assert_eq!((a.rows, a.cols), (c.rows, c.cols));
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .zip(&c.data)
        .map(|((&x, &y), &z)| f(x, y, z))
        .collect();
    Mat::from_vec(a.rows, a.cols, data)
}

pub(crate) fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

// ------------------------------------------------------------------
// Quantized linear (forward caches the used operands for STE backward)
// ------------------------------------------------------------------

/// Forward result + the operands the backward pass replays (STE). On the
/// BF16 path `xu`/`wu` are plain copies — the clone is what keeps the
/// activation alive for backward; at these model sizes (<= 64x256 f32)
/// the copy is noise next to the GEMM.
struct LinOut {
    y: Mat,
    /// activation operand actually fed to the GEMM (quantized or not)
    xu: Mat,
    /// weight operand actually fed to the GEMM
    wu: Mat,
    oq: OpQuant,
}

fn linear(x: &Mat, w: &Mat, oq: &OpQuant) -> LinOut {
    match oq.mode {
        QuantKind::Bf16 => LinOut {
            y: matmul(x, w),
            xu: x.clone(),
            wu: w.clone(),
            oq: oq.clone(),
        },
        QuantKind::Fp8 => {
            let xu = Mat::from_vec(x.rows, x.cols, fp8_fake_quant(&x.data));
            let wu = Mat::from_vec(w.rows, w.cols, fp8_fake_quant(&w.data));
            LinOut { y: matmul(&xu, &wu), xu, wu, oq: oq.clone() }
        }
        QuantKind::Nvfp4 => {
            let xu = nvfp4::fake_quant_mat(x);
            let wu = if oq.scaling_2d {
                nvfp4::fake_quant_mat_2d(w, 16)
            } else {
                nvfp4::fake_quant_mat(w)
            };
            let mut y = matmul(&xu, &wu);
            if oq.hcp_frac > 0.0 {
                // HCP O2-B compensation over the top-k hot channels
                let dx = x.sub(&xu);
                let dw = w.sub(&wu);
                let k = ((oq.hcp_frac * x.cols as f64).ceil() as usize).max(1);
                let idx = hcp::top_k(&hcp::scores(&dx, &dw), k);
                matmul_into(&dx.gather_cols(&idx), &wu.gather_rows(&idx), &mut y, true);
                matmul_into(&xu.gather_cols(&idx), &dw.gather_rows(&idx), &mut y, true);
            }
            LinOut { y, xu, wu, oq: oq.clone() }
        }
    }
}

/// Forward-only quantized linear for the serving path.
///
/// Matches `linear`'s forward math with one deliberate difference:
/// everything batch-shaped happens *per activation row*. Training
/// quantizes the whole (batch*seq, d) activation as one tensor — the
/// global NVFP4/FP8 encode scale and the HCP hot-channel selection both
/// span the batch, so a request's output would depend on whichever other
/// requests share its decode batch. Here each token row gets its own
/// encode scale, its own 1x16 blocks (every native width — d=32, ff=64 —
/// is a multiple of the 16-element block) and its own hot-channel
/// selection, which keeps greedy generations bit-identical at any batch
/// size (the serve contract). Weights quantize whole, as in training:
/// they are batch-independent by construction.
/// A weight pre-processed for serving: the quantized operand the GEMM
/// actually consumes, plus (on the HCP path) the residual and the
/// per-channel weight-score term. Weights are frozen at inference time,
/// so `Engine` computes this once per parameter at load instead of
/// re-quantizing every layer op on every decode step.
pub(crate) struct PreparedWeight {
    /// the operand fed to the GEMM (identity copy on the BF16 path)
    pub wu: Mat,
    /// `wu` pre-packed into the GEMM's B-panel layout — the packed-weight
    /// cache. Frozen serve weights set this once at model load so no
    /// decode/prefill GEMM ever re-packs them; `matmul_packed` is bitwise
    /// identical to `matmul`, so the cache is invisible in outputs. None
    /// on the one-shot paths that prepare a weight per call.
    pub wu_panels: Option<PackedMat>,
    /// W - Wq, present only when HCP compensation is on
    pub dw: Option<Mat>,
    /// mean |dW_j,:| per channel (the row-independent score term)
    pub wscore: Option<Vec<f64>>,
    /// real packed-NVFP4 compute operand (`--packed-compute` serve mode);
    /// when set, the fake-quant fields above stay empty — that's the
    /// resident-memory win the mode exists for
    pub packed: Option<PackedComputeWeight>,
}

/// The `--packed-compute` operand: the frozen weight resident as packed
/// NVFP4 codes, with the HCP-persistent hot channels split out of the
/// packed matrix into a narrow f32 side-matrix (OSC's
/// channel-separation scheme, PAPERS.md). The hot rows are zeroed
/// *before* the global amax is taken, so the cold encode scale no longer
/// stretches over outlier channels.
pub(crate) struct PackedComputeWeight {
    /// cold channels, packed in B-panel order for the in-register kernel
    pub qmat: nvfp4::PackedQuantMat,
    /// sorted k-row indices of the hot channels
    pub hot_idx: Vec<usize>,
    /// hot rows of the original f32 weight, column-major:
    /// element (r, c) at `c * hot_idx.len() + r`
    pub hot: Vec<f32>,
}

/// Quantize one weight per the op's forward recipe (serving path).
pub(crate) fn prepare_weight(w: &Mat, oq: &OpQuant) -> PreparedWeight {
    match oq.mode {
        QuantKind::Bf16 => {
            PreparedWeight { wu: w.clone(), wu_panels: None, dw: None, wscore: None, packed: None }
        }
        QuantKind::Fp8 => PreparedWeight {
            wu: Mat::from_vec(w.rows, w.cols, fp8_fake_quant(&w.data)),
            wu_panels: None,
            dw: None,
            wscore: None,
            packed: None,
        },
        QuantKind::Nvfp4 => {
            let wu = if oq.scaling_2d {
                nvfp4::fake_quant_mat_2d(w, 16)
            } else {
                nvfp4::fake_quant_mat(w)
            };
            if oq.hcp_frac > 0.0 {
                let dw = w.sub(&wu);
                let wscore: Vec<f64> = (0..dw.rows)
                    .map(|j| {
                        dw.row(j).iter().map(|&v| v.abs() as f64).sum::<f64>()
                            / dw.cols as f64
                    })
                    .collect();
                PreparedWeight {
                    wu,
                    wu_panels: None,
                    dw: Some(dw),
                    wscore: Some(wscore),
                    packed: None,
                }
            } else {
                PreparedWeight { wu, wu_panels: None, dw: None, wscore: None, packed: None }
            }
        }
    }
}

/// `prepare_weight` plus the packed-weight cache: the quantized operand
/// is additionally packed into B panels once, so every subsequent GEMM
/// over this weight skips the per-call pack. Used by the serve engine at
/// model-load time (weights are frozen there). Once the panels exist the
/// row-major `wu` has exactly one remaining reader — the HCP
/// compensation loop (which needs `dw` alongside it) — so on non-HCP ops
/// the duplicate is freed instead of doubling resident weight memory for
/// the engine's lifetime.
pub(crate) fn prepare_weight_cached(w: &Mat, oq: &OpQuant) -> PreparedWeight {
    let mut pw = prepare_weight(w, oq);
    pw.wu_panels = Some(PackedMat::pack(&pw.wu));
    if pw.dw.is_none() {
        pw.wu = Mat::from_vec(0, 0, Vec::new());
    }
    pw
}

/// The `--packed-compute` preparation: NVFP4 ops keep the weight
/// resident as packed codes + a hot-channel f32 side-matrix instead of a
/// dense fake-quantized f32 copy (~4.5 bits/weight instead of 32). Hot
/// channels come from the weight-side HCP score — mean |dW_j,:| of the
/// transient fake-quant residual — the persistent half of the online HCP
/// selection; `hcp_frac` sizes the split exactly as on the fake-quant
/// path. Non-NVFP4 ops fall back to [`prepare_weight_cached`].
pub(crate) fn prepare_weight_packed(w: &Mat, oq: &OpQuant) -> PreparedWeight {
    if oq.mode != QuantKind::Nvfp4 {
        return prepare_weight_cached(w, oq);
    }
    let wu = if oq.scaling_2d {
        nvfp4::fake_quant_mat_2d(w, 16)
    } else {
        nvfp4::fake_quant_mat(w)
    };
    let dw = w.sub(&wu);
    let wscore: Vec<f64> = (0..dw.rows)
        .map(|j| dw.row(j).iter().map(|&v| v.abs() as f64).sum::<f64>() / dw.cols as f64)
        .collect();
    let h = if oq.hcp_frac > 0.0 {
        (((oq.hcp_frac * w.rows as f64).ceil() as usize).max(1)).min(w.rows)
    } else {
        0
    };
    let hot_idx = {
        let mut v = hcp::top_k(&wscore, h);
        v.sort_unstable();
        v
    };
    // Zero the hot rows BEFORE the global amax: the cold-only encode
    // scale no longer stretches over outlier channels (the OSC accuracy
    // win), and the zeroed rows decode to exact 0.0 so the side-GEMM
    // owns the hot channels alone.
    let mut cold = w.clone();
    let mut hot = vec![0.0f32; hot_idx.len() * w.cols];
    for (r, &j) in hot_idx.iter().enumerate() {
        for c in 0..w.cols {
            hot[c * hot_idx.len() + r] = w.at(j, c);
            *cold.at_mut(j, c) = 0.0;
        }
    }
    let qmat = nvfp4::PackedQuantMat::pack(&cold);
    PreparedWeight {
        wu: Mat::from_vec(0, 0, Vec::new()),
        wu_panels: None,
        dw: None,
        wscore: None,
        packed: Some(PackedComputeWeight { qmat, hot_idx, hot }),
    }
}

/// The GEMM over a prepared weight: through the packed-panel cache when
/// present, else packing per call as before. Both are bitwise the same
/// product.
fn gemm_prepared(x: &Mat, pw: &PreparedWeight) -> Mat {
    match &pw.wu_panels {
        Some(panels) => matmul_packed(x, panels),
        None => matmul(x, &pw.wu),
    }
}

/// Per-row HCP observer: called with (hot-channel indices, total residual
/// energy ‖x - quant(x)‖², hot-channel residual energy) for every
/// activation row an HCP-compensated op processes. The energies are
/// computed only when an observer is attached, so the uninstrumented
/// decode path pays nothing (`chon serve --obs-outliers` telemetry).
pub(crate) type HcpRowObserver<'a> = &'a dyn Fn(&[usize], f64, f64);

/// Forward quantized linear over a pre-processed weight.
pub(crate) fn infer_linear_prepared(x: &Mat, pw: &PreparedWeight, oq: &OpQuant) -> Mat {
    infer_linear_prepared_obs(x, pw, oq, None)
}

/// `infer_linear_prepared` with an optional per-row HCP observer. The
/// forward math is bitwise identical with or without the observer — it
/// only reads the residual the compensation loop already holds.
pub(crate) fn infer_linear_prepared_obs(
    x: &Mat,
    pw: &PreparedWeight,
    oq: &OpQuant,
    obs: Option<HcpRowObserver<'_>>,
) -> Mat {
    let per_row = |f: &dyn Fn(&[f32]) -> Vec<f32>| -> Mat {
        let mut data = Vec::with_capacity(x.data.len());
        for i in 0..x.rows {
            data.extend(f(x.row(i)));
        }
        Mat::from_vec(x.rows, x.cols, data)
    };
    match oq.mode {
        QuantKind::Bf16 => gemm_prepared(x, pw),
        QuantKind::Fp8 => {
            let xu = per_row(&|r| fp8_fake_quant(r));
            gemm_prepared(&xu, pw)
        }
        QuantKind::Nvfp4 => {
            if let Some(pc) = &pw.packed {
                // Real packed compute: activations fake-quantize per row
                // (batch invariant as before), cold channels run through
                // the in-register dequant kernel, hot channels through an
                // f32 side-GEMM on the RAW activations — full precision
                // on both sides of the split (OSC). Per output element
                // the chain is fixed, so the mode is bit-identical across
                // batch sizes, SIMD levels, and thread counts. The HCP
                // observer never fires here: the split is persistent
                // (weight-side), there is no per-row selection to tap.
                let xu = per_row(&|r| nvfp4::fake_quant(r, nvfp4::Rounding::Rtn, None));
                let mut y = matmul_quant_packed(&xu, &pc.qmat);
                let h = pc.hot_idx.len();
                if h > 0 {
                    for i in 0..x.rows {
                        let xr = x.row(i);
                        let yr = y.row_mut(i);
                        for (c, yv) in yr.iter_mut().enumerate() {
                            let hcol = &pc.hot[c * h..(c + 1) * h];
                            let mut acc = 0.0f32;
                            for (r, &j) in pc.hot_idx.iter().enumerate() {
                                acc += xr[j] * hcol[r];
                            }
                            *yv += acc;
                        }
                    }
                }
                return y;
            }
            let xu = per_row(&|r| nvfp4::fake_quant(r, nvfp4::Rounding::Rtn, None));
            let mut y = gemm_prepared(&xu, pw);
            if let (Some(dw), Some(wscore)) = (&pw.dw, &pw.wscore) {
                let k = ((oq.hcp_frac * x.cols as f64).ceil() as usize).max(1);
                for i in 0..x.rows {
                    let xr = x.row(i);
                    let xur = xu.row(i);
                    let scores: Vec<f64> = (0..x.cols)
                        .map(|j| (xr[j] - xur[j]).abs() as f64 + wscore[j])
                        .collect();
                    let idx = hcp::top_k(&scores, k);
                    if let Some(cb) = obs {
                        let mut resid = 0.0f64;
                        for j in 0..x.cols {
                            let d = (xr[j] - xur[j]) as f64;
                            resid += d * d;
                        }
                        let mut hot = 0.0f64;
                        for &j in &idx {
                            let d = (xr[j] - xur[j]) as f64;
                            hot += d * d;
                        }
                        cb(&idx, resid, hot);
                    }
                    for &j in &idx {
                        let dxj = xr[j] - xur[j];
                        let xuj = xur[j];
                        let wur = pw.wu.row(j);
                        let dwr = dw.row(j);
                        let yr = y.row_mut(i);
                        for c in 0..yr.len() {
                            // dx·Wq + Xq·dw over the hot channels (O2-B)
                            yr[c] += dxj * wur[c] + xuj * dwr[c];
                        }
                    }
                }
            }
            y
        }
    }
}

/// One-shot convenience wrapper (tests / non-hot callers): prepare the
/// weight and apply it. The serve engine prepares once and calls
/// `infer_linear_prepared` directly.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn infer_linear(x: &Mat, w: &Mat, oq: &OpQuant) -> Mat {
    infer_linear_prepared(x, &prepare_weight(w, oq), oq)
}

/// Wgrad with the backward recipe: optional RHT over the token
/// (contraction) dim, then NVFP4 fake-quant of both operands — SR on the
/// gradient side when the recipe asks for it.
fn wgrad_quantized(c: &LinOut, dy: &Mat, rng: &mut Rng) -> Mat {
    let rows = c.xu.rows;
    let (xt, dyt) = if c.oq.rht && rows.is_power_of_two() {
        let signs = rht::random_signs(rows, rng);
        (rht::rht(&c.xu.transpose(), &signs), rht::rht(&dy.transpose(), &signs))
    } else {
        (c.xu.transpose(), dy.transpose())
    };
    let quant = |m: &Mat, sr: bool, rng: &mut Rng| -> Mat {
        if m.data.len() % nvfp4::BLOCK != 0 {
            return m.clone();
        }
        let rounding = if sr { nvfp4::Rounding::Sr } else { nvfp4::Rounding::Rtn };
        Mat::from_vec(m.rows, m.cols, nvfp4::fake_quant(&m.data, rounding, Some(rng)))
    };
    let xq = quant(&xt, false, rng);
    let dyq = quant(&dyt, c.oq.sr, rng);
    // dw = X^T dY == (H X)^T (H dY): xq is (d_in, rows), dyq is (d_out, rows)
    matmul(&xq, &dyq.transpose())
}

/// STE backward of one linear: returns (dx, dw).
fn linear_bwd(c: &LinOut, dy: &Mat, rng: &mut Rng) -> (Mat, Mat) {
    let dx = matmul(dy, &c.wu.transpose());
    let dw = if c.oq.mode == QuantKind::Nvfp4 {
        wgrad_quantized(c, dy, rng)
    } else {
        matmul(&c.xu.transpose(), dy)
    };
    (dx, dw)
}

// ------------------------------------------------------------------
// Norms + losses
// ------------------------------------------------------------------

const RMS_EPS: f64 = 1e-6;

pub(crate) fn rmsnorm(x: &Mat, gamma: &Mat) -> (Mat, Vec<f32>) {
    let mut out = Mat::zeros(x.rows, x.cols);
    let mut rs = Vec::with_capacity(x.rows);
    let g = gamma.row(0).to_vec();
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f64 =
            row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.cols as f64;
        let r = (ms + RMS_EPS).sqrt() as f32;
        let dst = out.row_mut(i);
        for (j, &v) in row.iter().enumerate() {
            dst[j] = v / r * g[j];
        }
        rs.push(r);
    }
    (out, rs)
}

fn rmsnorm_bwd(
    x: &Mat,
    gamma: &Mat,
    rs: &[f32],
    dy: &Mat,
    dgamma: &mut Mat,
) -> Mat {
    let d = x.cols as f32;
    let mut dx = Mat::zeros(x.rows, x.cols);
    let g = gamma.row(0).to_vec();
    for i in 0..x.rows {
        let r = rs[i];
        let xr = x.row(i);
        let dyr = dy.row(i);
        let mut dot = 0.0f64;
        for j in 0..x.cols {
            dot += (dyr[j] * g[j]) as f64 * xr[j] as f64;
        }
        let coeff = dot as f32 / (d * r * r * r);
        let dgr = dgamma.row_mut(0);
        for j in 0..x.cols {
            dgr[j] += dyr[j] * xr[j] / r;
        }
        let dxr = dx.row_mut(i);
        for j in 0..x.cols {
            dxr[j] = dyr[j] * g[j] / r - xr[j] * coeff;
        }
    }
    dx
}

/// Cross entropy over rows; returns (loss, accuracy, dlogits).
fn cross_entropy(logits: &Mat, targets: &[i32]) -> (f32, f32, Mat) {
    let (n, v) = (logits.rows, logits.cols);
    assert_eq!(targets.len(), n);
    let mut dl = Mat::zeros(n, v);
    let mut loss = 0.0f64;
    let mut hits = 0usize;
    for i in 0..n {
        let row = logits.row(i);
        let t = (targets[i] as usize) % v;
        let mut mx = f32::NEG_INFINITY;
        let mut argmax = 0;
        for (j, &x) in row.iter().enumerate() {
            if x > mx {
                mx = x;
                argmax = j;
            }
        }
        if argmax == t {
            hits += 1;
        }
        let mut z = 0.0f64;
        for &x in row {
            z += ((x - mx) as f64).exp();
        }
        let logz = z.ln() + mx as f64;
        loss -= row[t] as f64 - logz;
        let drow = dl.row_mut(i);
        for j in 0..v {
            let p = ((row[j] as f64 - logz).exp()) as f32;
            drow[j] = (p - if j == t { 1.0 } else { 0.0 }) / n as f32;
        }
    }
    (loss as f32 / n as f32, hits as f32 / n as f32, dl)
}

// ------------------------------------------------------------------
// Forward pass with caches
// ------------------------------------------------------------------

struct LayerCache {
    x_in: Mat,
    r1: Vec<f32>,
    lq: LinOut,
    lk: LinOut,
    lv: LinOut,
    lgk: Option<LinOut>,
    lg: Option<LinOut>,
    sgk: Option<Mat>,
    sg: Option<Mat>,
    /// modulated key (GLA) or the raw key (SA)
    kp: Mat,
    /// per-batch attention weight matrices (masked+scaled / softmaxed)
    att: Vec<Mat>,
    /// masked pre-softmax scores, flattened (SA diagnostics only)
    presoftmax: Vec<f32>,
    ao: Mat,
    /// input to W_o (gated context for GLA, context for SA)
    o: Mat,
    lo: LinOut,
    x_mid: Mat,
    r2: Vec<f32>,
    lup: LinOut,
    lgate: LinOut,
    sg2: Mat,
    silu: Mat,
    act: Mat,
    ldown: LinOut,
}

struct FwdCache {
    token_ids: Vec<usize>,
    layers: Vec<LayerCache>,
    xf: Mat,
    rf: Vec<f32>,
    lhead: LinOut,
}

fn forward_cache(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params: &[Mat],
    tokens: &[i32],
) -> FwdCache {
    let (d, bt) = (cfg.d, tokens.len());
    let seq = cfg.seq;
    assert_eq!(bt % seq, 0, "token count {bt} not a multiple of seq {seq}");
    let nb = bt / seq;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    let embed = &params[0];
    let token_ids: Vec<usize> =
        tokens.iter().map(|&t| (t as usize) % cfg.vocab).collect();
    let mut x = Mat::zeros(bt, d);
    for (i, &t) in token_ids.iter().enumerate() {
        x.row_mut(i).copy_from_slice(embed.row(t));
    }

    let mut layers = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let oq = |op: &str| op_quant(rec, cfg.arch, l, cfg.layers, op);
        let p = |slot: &str| &params[pidx(cfg, l, slot)];

        let x_in = x.clone();
        let (h, r1) = rmsnorm(&x_in, p("attn_norm"));
        let lq = linear(&h, p("wq"), &oq("attn.q"));
        let lk = linear(&h, p("wk"), &oq("attn.k"));
        let lv = linear(&h, p("wv"), &oq("attn.v"));

        let (lgk, lg, sgk, sg, kp);
        match cfg.arch {
            Arch::Gla => {
                let gk = linear(&h, p("wgk"), &oq("attn.gk"));
                let g = linear(&h, p("wg"), &oq("attn.g"));
                let sgk_m = map1(&gk.y, sigmoid);
                let sg_m = map1(&g.y, sigmoid);
                kp = map2(&lk.y, &sgk_m, |k, s| k * s);
                lgk = Some(gk);
                lg = Some(g);
                sgk = Some(sgk_m);
                sg = Some(sg_m);
            }
            Arch::Sa => {
                kp = lk.y.clone();
                lgk = None;
                lg = None;
                sgk = None;
                sg = None;
            }
        }

        let mut ao = Mat::zeros(bt, d);
        let mut att = Vec::with_capacity(nb);
        let mut presoftmax = Vec::new();
        for b in 0..nb {
            let s0 = b * seq;
            let qb = rows_block(&lq.y, s0, seq);
            let kb = rows_block(&kp, s0, seq);
            let vb = rows_block(&lv.y, s0, seq);
            let mut w_att = matmul(&qb, &kb.transpose());
            match cfg.arch {
                Arch::Gla => {
                    // causal mask + 1/((t+1) sqrt(d)) row normalization
                    for t in 0..seq {
                        let ct = inv_sqrt_d / (t as f32 + 1.0);
                        let row = w_att.row_mut(t);
                        for (s, val) in row.iter_mut().enumerate() {
                            *val = if s <= t { *val * ct } else { 0.0 };
                        }
                    }
                }
                Arch::Sa => {
                    // causal softmax over s <= t
                    for t in 0..seq {
                        let row = w_att.row_mut(t);
                        let mut mx = f32::NEG_INFINITY;
                        for (s, val) in row.iter_mut().enumerate().take(t + 1) {
                            *val *= inv_sqrt_d;
                            presoftmax.push(*val);
                            mx = mx.max(*val);
                            let _ = s;
                        }
                        let mut z = 0.0f32;
                        for val in row.iter_mut().take(t + 1) {
                            *val = (*val - mx).exp();
                            z += *val;
                        }
                        for (s, val) in row.iter_mut().enumerate() {
                            *val = if s <= t { *val / z } else { 0.0 };
                        }
                    }
                }
            }
            set_rows_block(&mut ao, s0, &matmul(&w_att, &vb));
            att.push(w_att);
        }

        let o = match &sg {
            Some(sg_m) => map2(&ao, sg_m, |a, s| a * s),
            None => ao.clone(),
        };
        let lo = linear(&o, p("wo"), &oq("attn.o"));
        let mut x_mid = x_in.clone();
        x_mid.add_assign(&lo.y);

        let (h2, r2) = rmsnorm(&x_mid, p("mlp_norm"));
        let lup = linear(&h2, p("w_up"), &oq("mlp.up"));
        let lgate = linear(&h2, p("w_gate"), &oq("mlp.gate"));
        let sg2 = map1(&lgate.y, sigmoid);
        let silu = map2(&lgate.y, &sg2, |z, s| z * s);
        let act = map2(&lup.y, &silu, |u, s| u * s);
        let ldown = linear(&act, p("w_down"), &oq("mlp.down"));
        let mut x_out = x_mid.clone();
        x_out.add_assign(&ldown.y);
        x = x_out;

        layers.push(LayerCache {
            x_in,
            r1,
            lq,
            lk,
            lv,
            lgk,
            lg,
            sgk,
            sg,
            kp,
            att,
            presoftmax,
            ao,
            o,
            lo,
            x_mid,
            r2,
            lup,
            lgate,
            sg2,
            silu,
            act,
            ldown,
        });
    }

    let (hf, rf) = rmsnorm(&x, &params[final_norm_idx(cfg)]);
    let lhead = linear(&hf, &params[lm_head_idx(cfg)], &crate::runtime::native::recipe::BF16_OP);
    FwdCache { token_ids, layers, xf: x, rf, lhead }
}

// ------------------------------------------------------------------
// Backward pass
// ------------------------------------------------------------------

fn backward(
    cfg: &ModelCfg,
    params: &[Mat],
    cache: &FwdCache,
    dlogits: &Mat,
    rng: &mut Rng,
) -> Vec<Mat> {
    let seq = cfg.seq;
    let inv_sqrt_d = 1.0 / (cfg.d as f32).sqrt();
    let mut grads: Vec<Mat> =
        params.iter().map(|p| Mat::zeros(p.rows, p.cols)).collect();

    // lm_head + final norm
    let (dhf, dw_head) = linear_bwd(&cache.lhead, dlogits, rng);
    grads[lm_head_idx(cfg)].add_assign(&dw_head);
    let mut dgf = Mat::zeros(1, cfg.d);
    let mut dx = rmsnorm_bwd(&cache.xf, &params[final_norm_idx(cfg)], &cache.rf, &dhf, &mut dgf);
    grads[final_norm_idx(cfg)].add_assign(&dgf);

    for l in (0..cfg.layers).rev() {
        let c = &cache.layers[l];
        let gi = |slot: &str| pidx(cfg, l, slot);

        // MLP block: x_out = x_mid + down(act)
        let (dact, dw_down) = linear_bwd(&c.ldown, &dx, rng);
        grads[gi("w_down")].add_assign(&dw_down);
        let dup = map2(&dact, &c.silu, |a, s| a * s);
        let dgate = {
            // d silu(z) = sig(z) (1 + z (1 - sig(z)))
            let dsilu = map2(&c.lgate.y, &c.sg2, |z, s| s * (1.0 + z * (1.0 - s)));
            map3(&dact, &c.lup.y, &dsilu, |a, u, ds| a * u * ds)
        };
        let (dh2a, dw_up) = linear_bwd(&c.lup, &dup, rng);
        grads[gi("w_up")].add_assign(&dw_up);
        let (dh2b, dw_gate) = linear_bwd(&c.lgate, &dgate, rng);
        grads[gi("w_gate")].add_assign(&dw_gate);
        let mut dh2 = dh2a;
        dh2.add_assign(&dh2b);
        let mut dgn = Mat::zeros(1, cfg.d);
        let dxm = rmsnorm_bwd(&c.x_mid, &params[gi("mlp_norm")], &c.r2, &dh2, &mut dgn);
        grads[gi("mlp_norm")].add_assign(&dgn);
        dx.add_assign(&dxm);

        // Attention block: x_mid = x_in + wo(o)
        let (do_, dw_o) = linear_bwd(&c.lo, &dx, rng);
        grads[gi("wo")].add_assign(&dw_o);
        let (dao, dg_pre) = match (&c.sg, &c.lg) {
            (Some(sg), Some(_)) => {
                let dao = map2(&do_, sg, |g, s| g * s);
                let dg = map3(&do_, &c.ao, sg, |g, a, s| g * a * s * (1.0 - s));
                (dao, Some(dg))
            }
            _ => (do_, None),
        };

        let bt = c.lq.y.rows;
        let nb = bt / seq;
        let mut dq = Mat::zeros(bt, cfg.d);
        let mut dkp = Mat::zeros(bt, cfg.d);
        let mut dv = Mat::zeros(bt, cfg.d);
        for b in 0..nb {
            let s0 = b * seq;
            let daob = rows_block(&dao, s0, seq);
            let qb = rows_block(&c.lq.y, s0, seq);
            let kb = rows_block(&c.kp, s0, seq);
            let vb = rows_block(&c.lv.y, s0, seq);
            let w_att = &c.att[b];
            let dw_att = matmul(&daob, &vb.transpose());
            set_rows_block(&mut dv, s0, &matmul(&w_att.transpose(), &daob));
            let mut ds = dw_att;
            match cfg.arch {
                Arch::Gla => {
                    for t in 0..seq {
                        let ct = inv_sqrt_d / (t as f32 + 1.0);
                        let row = ds.row_mut(t);
                        for (s, val) in row.iter_mut().enumerate() {
                            *val = if s <= t { *val * ct } else { 0.0 };
                        }
                    }
                }
                Arch::Sa => {
                    // softmax backward: dS = P (dP - <dP, P>), then 1/sqrt(d)
                    for t in 0..seq {
                        let p_row = w_att.row(t).to_vec();
                        let row = ds.row_mut(t);
                        let mut dot = 0.0f64;
                        for s in 0..seq {
                            dot += (row[s] * p_row[s]) as f64;
                        }
                        for s in 0..seq {
                            row[s] =
                                p_row[s] * (row[s] - dot as f32) * inv_sqrt_d;
                        }
                    }
                }
            }
            set_rows_block(&mut dq, s0, &matmul(&ds, &kb));
            set_rows_block(&mut dkp, s0, &matmul(&ds.transpose(), &qb));
        }

        let (dk, dgk_pre) = match (&c.sgk, &c.lgk) {
            (Some(sgk), Some(_)) => {
                let dk = map2(&dkp, sgk, |g, s| g * s);
                let dgk = map3(&dkp, &c.lk.y, sgk, |g, k, s| g * k * s * (1.0 - s));
                (dk, Some(dgk))
            }
            _ => (dkp, None),
        };

        let (mut dh, dw_q) = linear_bwd(&c.lq, &dq, rng);
        grads[gi("wq")].add_assign(&dw_q);
        let (dhk, dw_k) = linear_bwd(&c.lk, &dk, rng);
        grads[gi("wk")].add_assign(&dw_k);
        dh.add_assign(&dhk);
        let (dhv, dw_v) = linear_bwd(&c.lv, &dv, rng);
        grads[gi("wv")].add_assign(&dw_v);
        dh.add_assign(&dhv);
        if let (Some(dgk), Some(lgk)) = (&dgk_pre, &c.lgk) {
            let (dhgk, dw_gk) = linear_bwd(lgk, dgk, rng);
            grads[gi("wgk")].add_assign(&dw_gk);
            dh.add_assign(&dhgk);
        }
        if let (Some(dg), Some(lg)) = (&dg_pre, &c.lg) {
            let (dhg, dw_g) = linear_bwd(lg, dg, rng);
            grads[gi("wg")].add_assign(&dw_g);
            dh.add_assign(&dhg);
        }

        let mut dga = Mat::zeros(1, cfg.d);
        let dxi = rmsnorm_bwd(&c.x_in, &params[gi("attn_norm")], &c.r1, &dh, &mut dga);
        grads[gi("attn_norm")].add_assign(&dga);
        dx.add_assign(&dxi);
    }

    // embedding scatter-add
    for (i, &t) in cache.token_ids.iter().enumerate() {
        let src = dx.row(i).to_vec();
        let dst = grads[0].row_mut(t);
        for (a, b) in dst.iter_mut().zip(&src) {
            *a += b;
        }
    }
    grads
}

// ------------------------------------------------------------------
// Optimizer + schedule
// ------------------------------------------------------------------

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const BASE_LR: f32 = 3e-3;
const WARMUP_STEPS: f32 = 10.0;
const GRAD_CLIP: f64 = 1.0;

/// Warmup + cosine decay to 10% of base over `total` steps.
///
/// `total` is the model's baked `total_steps` horizon — the same
/// semantics as the AOT artifacts, whose lowered schedule is fixed at
/// trace time. `--steps` changes only how many steps the trainer loops;
/// running past the horizon holds the 10% floor.
pub fn lr_at(step: usize, total: usize) -> f32 {
    let w = ((step as f32 + 1.0) / WARMUP_STEPS).min(1.0);
    let prog = (step as f32 / total.max(1) as f32).min(1.0);
    let cos = 0.1 + 0.9 * 0.5 * (1.0 + (std::f32::consts::PI * prog).cos());
    BASE_LR * w * cos
}

/// In-place Adam with global-norm clipping; returns the pre-clip norm.
/// `pub(crate)` so the shard engine can apply the identical update to the
/// allreduced gradient.
pub(crate) fn adam_update(
    params: &mut [Mat],
    m: &mut [Mat],
    v: &mut [Mat],
    grads: &[Mat],
    step: usize,
    lr: f32,
) -> f32 {
    let mut norm_sq = 0.0f64;
    for g in grads {
        norm_sq += g.frob_sq();
    }
    let gnorm = norm_sq.sqrt();
    let clip = (GRAD_CLIP / gnorm.max(1e-12)).min(1.0) as f32;
    let t = (step + 1) as i32;
    let bc1 = 1.0 - ADAM_B1.powi(t);
    let bc2 = 1.0 - ADAM_B2.powi(t);
    for (((p, mm), vv), g) in
        params.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(grads)
    {
        for i in 0..p.data.len() {
            let gi = g.data[i] * clip;
            mm.data[i] = ADAM_B1 * mm.data[i] + (1.0 - ADAM_B1) * gi;
            vv.data[i] = ADAM_B2 * vv.data[i] + (1.0 - ADAM_B2) * gi * gi;
            let mh = mm.data[i] / bc1;
            let vh = vv.data[i] / bc2;
            p.data[i] -= lr * mh / (vh.sqrt() + ADAM_EPS);
        }
    }
    gnorm as f32
}

// ------------------------------------------------------------------
// The executable entry points
// ------------------------------------------------------------------

/// Forward + backward only: the recipe's quantized loss and parameter
/// gradients for one token window, no optimizer state touched. This is
/// the per-shard unit of the data-parallel engine — each shard runs it
/// over its own rows with its own RNG stream, and the allreduced result
/// feeds a single `adam_update`.
pub(crate) fn loss_and_grads(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params: &[Mat],
    tokens: &[i32],
    targets: &[i32],
    rng: &mut Rng,
) -> (f32, Vec<Mat>) {
    let cache = forward_cache(cfg, rec, params, tokens);
    let (loss, _acc, dlogits) = cross_entropy(&cache.lhead.y, targets);
    let grads = backward(cfg, params, &cache, &dlogits, rng);
    (loss, grads)
}

/// One optimizer step. Returns (params', m', v', loss, grad_norm, lr).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params_in: &[HostTensor],
    m_in: &[HostTensor],
    v_in: &[HostTensor],
    step: usize,
    tokens: &[i32],
    targets: &[i32],
    seed: u64,
) -> (Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>, f32, f32, f32) {
    let specs = param_specs(cfg);
    let mut params = params_to_mats(params_in);
    let mut m = params_to_mats(m_in);
    let mut v = params_to_mats(v_in);
    // per-(seed, step) stream so SR is deterministic and reproducible
    let mut rng = Rng::new(seed ^ 0x5EED_0001).fold_in(step as u64);

    let (loss, grads) = loss_and_grads(cfg, rec, &params, tokens, targets, &mut rng);
    let lr = lr_at(step, cfg.total_steps);
    let gnorm = adam_update(&mut params, &mut m, &mut v, &grads, step, lr);

    (
        mats_to_tensors(&specs, params),
        mats_to_tensors(&specs, m),
        mats_to_tensors(&specs, v),
        loss,
        gnorm,
        lr,
    )
}

/// Held-out loss + accuracy under the recipe's forward quantization.
pub fn eval_step(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params_in: &[HostTensor],
    tokens: &[i32],
    targets: &[i32],
) -> (f32, f32) {
    let params = params_to_mats(params_in);
    let cache = forward_cache(cfg, rec, &params, tokens);
    let (loss, acc, _) = cross_entropy(&cache.lhead.y, targets);
    (loss, acc)
}

/// Forward logits (batch*seq, vocab), row-major.
pub fn forward_logits(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params_in: &[HostTensor],
    tokens: &[i32],
) -> Mat {
    let params = params_to_mats(params_in);
    forward_cache(cfg, rec, &params, tokens).lhead.y
}

/// Diagnosed components per layer, in metric order.
fn diag_components(arch: Arch) -> &'static [(&'static str, &'static str)] {
    // (component tag, backing weight slot)
    match arch {
        Arch::Gla => &[
            ("attn.q", "wq"),
            ("attn.k", "wk"),
            ("attn.v", "wv"),
            ("attn.gk", "wgk"),
            ("attn.g", "wg"),
            ("attn.o", "wo"),
            ("mlp.up", "w_up"),
            ("mlp.gate", "w_gate"),
            ("mlp.down", "w_down"),
        ],
        Arch::Sa => &[
            ("attn.q", "wq"),
            ("attn.k", "wk"),
            ("attn.v", "wv"),
            ("attn.o", "wo"),
            ("mlp.up", "w_up"),
            ("mlp.gate", "w_gate"),
            ("mlp.down", "w_down"),
        ],
    }
}

const ACT_METRICS: [&str; 8] = [
    "act.kurt", "act.top1", "act.top3", "act.ftz", "act.qmse", "act.bkmin",
    "act.bkavg", "act.bkmax",
];
const WT_METRICS: [&str; 3] = ["wt.kurt", "wt.ftz", "wt.qmse"];

/// The diag artifact's metric slot names, in output order.
pub fn metric_names(cfg: &ModelCfg) -> Vec<String> {
    let mut names = Vec::new();
    for l in 0..cfg.layers {
        for (comp, _) in diag_components(cfg.arch) {
            for m in ACT_METRICS {
                names.push(format!("L{l}.{comp}.{m}"));
            }
            for m in WT_METRICS {
                names.push(format!("L{l}.{comp}.{m}"));
            }
        }
        names.push(format!("L{l}.mlp.alignment"));
        if cfg.arch == Arch::Sa {
            names.push(format!("L{l}.attn.presoftmax.kurt"));
            names.push(format!("L{l}.attn.presoftmax.max"));
            names.push(format!("L{l}.attn.postsoftmax.entropy"));
        }
    }
    names
}

/// Channel-map components (tag, channel count) for the diag outputs.
pub fn diag_map_shapes(cfg: &ModelCfg) -> Vec<(&'static str, usize)> {
    match cfg.arch {
        Arch::Gla => {
            vec![("attn_o", cfg.d), ("mlp_up", cfg.ff), ("attn_gk", cfg.d)]
        }
        Arch::Sa => vec![("attn_o", cfg.d), ("mlp_up", cfg.ff)],
    }
}

fn act_metric_values(x: &Mat, out: &mut Vec<f32>) {
    out.push(diagnostics::kurtosis(&x.data) as f32);
    let top = diagnostics::topk_magnitude(&x.data, 3);
    out.push(top.first().copied().unwrap_or(0.0));
    out.push(top.get(2).copied().unwrap_or(0.0));
    out.push(diagnostics::ftz(&x.data) as f32);
    out.push(diagnostics::quant_mse(&x.data) as f32);
    let bk = diagnostics::block_kurtosis(x, 16, 16);
    let s = diagnostics::summarize(&bk);
    out.push(s.min as f32);
    out.push(s.avg as f32);
    out.push(s.max as f32);
}

fn wt_metric_values(w: &Mat, out: &mut Vec<f32>) {
    out.push(diagnostics::kurtosis(&w.data) as f32);
    out.push(diagnostics::ftz(&w.data) as f32);
    out.push(diagnostics::quant_mse(&w.data) as f32);
}

/// Run the diagnostics probe: metric vector + per-layer channel maps.
pub fn diag_step(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params_in: &[HostTensor],
    tokens: &[i32],
) -> (Vec<f32>, Vec<Mat>) {
    let params = params_to_mats(params_in);
    let cache = forward_cache(cfg, rec, &params, tokens);

    let mut values = Vec::new();
    let map_shapes = diag_map_shapes(cfg);
    let mut maps: Vec<Mat> = map_shapes
        .iter()
        .map(|&(_, chans)| Mat::zeros(cfg.layers, chans))
        .collect();

    for (l, c) in cache.layers.iter().enumerate() {
        for (comp, wslot) in diag_components(cfg.arch) {
            let act: &Mat = match *comp {
                "attn.q" => &c.lq.y,
                "attn.k" => &c.lk.y,
                "attn.v" => &c.lv.y,
                "attn.gk" => &c.lgk.as_ref().unwrap().y,
                "attn.g" => &c.lg.as_ref().unwrap().y,
                "attn.o" => &c.o,
                "mlp.up" => &c.lup.y,
                "mlp.gate" => &c.lgate.y,
                "mlp.down" => &c.act,
                other => panic!("no activation for {other}"),
            };
            act_metric_values(act, &mut values);
            wt_metric_values(&params[pidx(cfg, l, wslot)], &mut values);
        }
        values.push(diagnostics::cosine_alignment(
            &params[pidx(cfg, l, "w_up")].transpose(),
            &params[pidx(cfg, l, "w_gate")].transpose(),
        ) as f32);
        if cfg.arch == Arch::Sa {
            values.push(diagnostics::kurtosis(&c.presoftmax) as f32);
            let mx = c.presoftmax.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            values.push(mx);
            // entropy of the causal softmax rows (zero-prob tail skipped)
            let mut h = 0.0f64;
            let mut rows = 0usize;
            for att in &c.att {
                for t in 0..att.rows {
                    let mut ent = 0.0f64;
                    for &p in &att.row(t)[..=t] {
                        if p > 0.0 {
                            ent -= (p as f64) * (p as f64).ln();
                        }
                    }
                    h += ent;
                    rows += 1;
                }
            }
            values.push((h / rows.max(1) as f64) as f32);
        }

        // channel maps
        for (mi, &(tag, _)) in map_shapes.iter().enumerate() {
            let src: Option<&Mat> = match tag {
                "attn_o" => Some(&c.o),
                "mlp_up" => Some(&c.lup.y),
                "attn_gk" => c.lgk.as_ref().map(|lin| &lin.y),
                _ => None,
            };
            if let Some(src) = src {
                let cm = diagnostics::channel_max(src);
                maps[mi].row_mut(l).copy_from_slice(&cm);
            }
        }
    }
    assert_eq!(values.len(), metric_names(cfg).len(), "diag schema drift");
    (values, maps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::recipe::recipe;

    fn toy_batch(cfg: &ModelCfg, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let n = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..n + 1)
            .map(|_| (rng.below(24) as i32) + 97) // ascii letters
            .collect();
        (toks[..n].to_vec(), toks[1..].to_vec())
    }

    /// The packed-weight cache (`prepare_weight_cached`) must be bitwise
    /// invisible: for every quant mode and activation batch shape on both
    /// sides of the GEMM's small-m dispatch edge, the packed and unpacked
    /// prepared forms produce identical output bits.
    #[test]
    fn cached_prepared_weight_is_bit_identical_to_uncached() {
        for rec_name in ["bf16", "fp8", "nvfp4", "chon"] {
            let rec = recipe(rec_name).unwrap();
            for op in ["attn.q", "mlp.up", "mlp.down"] {
                let oq = op_quant(&rec, Arch::Gla, 0, 2, op);
                let (k, n) = if op == "mlp.down" { (64, 32) } else { (32, 64) };
                let mut rng = Rng::new(17);
                let w = Mat::from_fn(k, n, |_, _| rng.normal() * 0.3);
                let plain = prepare_weight(&w, &oq);
                let cached = prepare_weight_cached(&w, &oq);
                assert!(cached.wu_panels.is_some());
                if cached.dw.is_some() {
                    // HCP compensation still reads wu rows — kept intact
                    assert_eq!(plain.wu.data, cached.wu.data);
                } else {
                    // no remaining reader: the duplicate must be freed
                    assert!(cached.wu.data.is_empty());
                }
                for rows in [1usize, 3, 8, 13] {
                    let x = Mat::from_fn(rows, k, |_, _| rng.normal());
                    let a = infer_linear_prepared(&x, &plain, &oq);
                    let b = infer_linear_prepared(&x, &cached, &oq);
                    assert_eq!(
                        a.data, b.data,
                        "{rec_name}/{op} rows={rows}: packed cache changed bits"
                    );
                }
            }
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let cfg = model_cfg("tiny_gla").unwrap();
        let a = init_params(&cfg, 0);
        let b = init_params(&cfg, 0);
        let c = init_params(&cfg, 1);
        assert_eq!(a.len(), param_specs(&cfg).len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.f32_data, y.f32_data);
        }
        assert!(a.iter().zip(&c).any(|(x, y)| x.f32_data != y.f32_data));
    }

    #[test]
    fn train_step_descends_on_repeated_batch() {
        // one repeated batch must be fit quickly: loss strictly decreases
        let cfg = model_cfg("tiny_gla").unwrap();
        let rec = recipe("bf16").unwrap();
        let mut params = init_params(&cfg, 0);
        let mut m: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros(p.dtype, p.shape.clone()))
            .collect();
        let mut v = m.clone();
        let (toks, tgts) = toy_batch(&cfg, 3);
        let mut losses = Vec::new();
        for step in 0..12 {
            let (p2, m2, v2, loss, gnorm, lr) =
                train_step(&cfg, &rec, &params, &m, &v, step, &toks, &tgts, 0);
            assert!(loss.is_finite() && gnorm.is_finite() && lr > 0.0);
            params = p2;
            m = m2;
            v = v2;
            losses.push(loss);
        }
        assert!(
            losses[11] < losses[0] - 0.5,
            "no descent: {} -> {}",
            losses[0],
            losses[11]
        );
    }

    #[test]
    fn train_step_is_bit_deterministic() {
        let cfg = model_cfg("tiny_gla").unwrap();
        let rec = recipe("chon").unwrap(); // exercises SR + RHT + HCP
        let params = init_params(&cfg, 7);
        let m: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros(p.dtype, p.shape.clone()))
            .collect();
        let v = m.clone();
        let (toks, tgts) = toy_batch(&cfg, 5);
        let a = train_step(&cfg, &rec, &params, &m, &v, 0, &toks, &tgts, 7);
        let b = train_step(&cfg, &rec, &params, &m, &v, 0, &toks, &tgts, 7);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert_eq!(x.f32_data, y.f32_data, "same (seed, step) must agree");
        }
        assert_eq!(a.3, b.3);
    }

    #[test]
    fn sa_forward_and_step_finite() {
        let cfg = model_cfg("tiny_sa").unwrap();
        let rec = recipe("nvfp4").unwrap();
        let params = init_params(&cfg, 1);
        let m: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros(p.dtype, p.shape.clone()))
            .collect();
        let v = m.clone();
        let (toks, tgts) = toy_batch(&cfg, 9);
        let (_, _, _, loss, gnorm, _) =
            train_step(&cfg, &rec, &params, &m, &v, 0, &toks, &tgts, 1);
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        assert!(gnorm.is_finite());
    }

    #[test]
    fn diag_schema_matches_names() {
        for model in ["tiny_gla", "tiny_sa"] {
            let cfg = model_cfg(model).unwrap();
            let rec = recipe("bf16").unwrap();
            let params = init_params(&cfg, 2);
            let (toks, _) = toy_batch(&cfg, 1);
            let (values, maps) = diag_step(&cfg, &rec, &params, &toks);
            assert_eq!(values.len(), metric_names(&cfg).len());
            assert!(values.iter().all(|v| v.is_finite()));
            assert_eq!(maps.len(), diag_map_shapes(&cfg).len());
            for (map, &(_, chans)) in maps.iter().zip(&diag_map_shapes(&cfg)) {
                assert_eq!((map.rows, map.cols), (cfg.layers, chans));
                assert!(map.data.iter().any(|&v| v > 0.0), "empty channel map");
            }
        }
    }

    #[test]
    fn logits_shape_and_finiteness() {
        let cfg = model_cfg("tiny_gla").unwrap();
        let rec = recipe("chon").unwrap();
        let params = init_params(&cfg, 3);
        let (toks, tgts) = toy_batch(&cfg, 2);
        let logits = forward_logits(&cfg, &rec, &params, &toks);
        assert_eq!((logits.rows, logits.cols), (cfg.batch * cfg.seq, cfg.vocab));
        assert!(logits.data.iter().all(|v| v.is_finite()));
        let (loss, acc) = eval_step(&cfg, &rec, &params, &toks, &tgts);
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn infer_linear_is_batch_invariant() {
        // the serve contract: row i of a batched call is bit-identical to
        // a batch-of-one call with that row, for every forward mode
        let mut rng = Rng::new(11);
        let x = Mat::from_fn(8, 32, |_, _| rng.normal());
        let w = Mat::from_fn(32, 32, |_, _| rng.normal() * 0.3);
        for oq in [
            crate::runtime::native::recipe::BF16_OP,
            OpQuant {
                mode: QuantKind::Fp8,
                scaling_2d: false,
                sr: false,
                rht: false,
                hcp_frac: 0.0,
            },
            OpQuant {
                mode: QuantKind::Nvfp4,
                scaling_2d: true,
                sr: true,
                rht: true,
                hcp_frac: 0.0909,
            },
        ] {
            let full = infer_linear(&x, &w, &oq);
            for i in 0..x.rows {
                let one = Mat::from_vec(1, x.cols, x.row(i).to_vec());
                let y1 = infer_linear(&one, &w, &oq);
                assert_eq!(full.row(i), y1.row(0), "row {i} mode {:?}", oq.mode);
            }
        }
    }

    #[test]
    fn packed_prepared_weight_matches_dense_reference() {
        // hot-channel-split correctness: packed cold GEMM + f32 side-GEMM
        // must agree with an f64 dense GEMM over (dequantized cold matrix,
        // original f32 hot rows) within float tolerance — the documented
        // accuracy contract of --packed-compute
        let mut rng = Rng::new(21);
        let w = Mat::from_fn(64, 48, |_, _| rng.normal() * 0.3);
        let oq = OpQuant {
            mode: QuantKind::Nvfp4,
            scaling_2d: true,
            sr: false,
            rht: false,
            hcp_frac: 0.0909,
        };
        let pw = prepare_weight_packed(&w, &oq);
        let pc = pw.packed.as_ref().unwrap();
        assert_eq!(pc.hot_idx.len(), 6); // ceil(0.0909 * 64)
        assert!(pw.wu.data.is_empty() && pw.wu_panels.is_none() && pw.dw.is_none());
        let deq = pc.qmat.dequantize_mat();
        for &j in &pc.hot_idx {
            assert!(deq.row(j).iter().all(|&v| v == 0.0), "hot row {j} not zeroed");
        }
        let x = Mat::from_fn(5, 64, |_, _| rng.normal());
        let y = infer_linear_prepared(&x, &pw, &oq);
        let mut xu = Mat::zeros(x.rows, x.cols);
        for i in 0..x.rows {
            xu.row_mut(i)
                .copy_from_slice(&nvfp4::fake_quant(x.row(i), nvfp4::Rounding::Rtn, None));
        }
        for i in 0..x.rows {
            for c in 0..48 {
                let mut want = 0.0f64;
                for k in 0..64 {
                    want += xu.at(i, k) as f64 * deq.at(k, c) as f64;
                }
                for &j in &pc.hot_idx {
                    want += x.at(i, j) as f64 * w.at(j, c) as f64;
                }
                let got = y.at(i, c) as f64;
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "({i},{c}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn packed_prepared_is_batch_invariant() {
        // the serve contract holds in --packed-compute mode too: row i of
        // a batched call is bit-identical to a batch-of-one call
        let mut rng = Rng::new(22);
        let w = Mat::from_fn(64, 32, |_, _| rng.normal() * 0.3);
        for hcp_frac in [0.0, 0.0909] {
            let oq = OpQuant {
                mode: QuantKind::Nvfp4,
                scaling_2d: false,
                sr: false,
                rht: false,
                hcp_frac,
            };
            let pw = prepare_weight_packed(&w, &oq);
            let x = Mat::from_fn(8, 64, |_, _| rng.normal());
            let full = infer_linear_prepared(&x, &pw, &oq);
            for i in 0..x.rows {
                let one = Mat::from_vec(1, x.cols, x.row(i).to_vec());
                let y1 = infer_linear_prepared(&one, &pw, &oq);
                assert_eq!(full.row(i), y1.row(0), "row {i} hcp={hcp_frac}");
            }
        }
    }

    #[test]
    fn packed_prepared_memory_and_fallback() {
        let mut rng = Rng::new(23);
        let w = Mat::from_fn(256, 64, |_, _| rng.normal() * 0.3);
        let oq = OpQuant {
            mode: QuantKind::Nvfp4,
            scaling_2d: true,
            sr: false,
            rht: false,
            hcp_frac: 0.0909,
        };
        let pw = prepare_weight_packed(&w, &oq);
        let pc = pw.packed.as_ref().unwrap();
        let dense = 256 * 64 * 4;
        let resident =
            pc.qmat.storage_bytes() + pc.hot.len() * 4 + pc.hot_idx.len() * 8;
        assert!(resident * 3 < dense, "resident {resident} vs dense {dense}");
        // non-NVFP4 ops fall back to the f32 packed-panel cache
        let bf = prepare_weight_packed(&w, &crate::runtime::native::recipe::BF16_OP);
        assert!(bf.packed.is_none() && bf.wu_panels.is_some());
    }

    #[test]
    fn lr_schedule_warms_up_and_decays() {
        assert!(lr_at(0, 200) < lr_at(9, 200));
        assert!(lr_at(199, 200) < lr_at(50, 200));
        assert!(lr_at(1000, 200) > 0.0); // clamps, never hits zero
    }
}
