//! Native recipe table — the Rust mirror of python/compile/recipe.py.
//!
//! A recipe is the Tab. 2 ablation unit; `op_quant` resolves the effective
//! per-operator quantization (last-N-layer protection, CHON post-QK
//! protection, SR/RHT/2D toggles, HCP channel fraction) exactly like the
//! Python side so the native engine runs the same ablation grid.

use anyhow::{bail, Result};

use crate::runtime::native::model::Arch;

/// HCP patched-channel fraction (App. C.1: 9.09%).
pub const HCP_FRAC: f64 = 0.0909;

/// Element format of one GEMM operand pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    Bf16,
    Fp8,
    Nvfp4,
}

/// One training recipe (the Tab. 2 row).
#[derive(Clone, Debug)]
pub struct NativeRecipe {
    pub name: String,
    pub mode: QuantKind,
    /// stochastic rounding on the backward (Wgrad) quantization
    pub sr: bool,
    /// randomized Hadamard transform on the Wgrad contraction dim
    pub rht: bool,
    /// 2D (16x16) weight block scaling instead of 1x16
    pub scaling_2d: bool,
    /// HCP patched-channel fraction (0 disables HCP)
    pub hcp_frac: f64,
    /// keep the last N layers fully BF16
    pub protect_last: usize,
    /// CHON post-QK protection (W_o + W_gk for LA, W_v for SA)
    pub post_qk: bool,
    /// Tab. 3 sensitivity mode: quantize exactly this op, all else BF16
    pub only_op: Option<String>,
}

impl NativeRecipe {
    fn base(name: &str) -> NativeRecipe {
        NativeRecipe {
            name: name.to_string(),
            mode: QuantKind::Nvfp4,
            sr: true,
            rht: true,
            scaling_2d: true,
            hcp_frac: 0.0,
            protect_last: 1,
            post_qk: false,
            only_op: None,
        }
    }
}

/// Resolve a recipe by name (mirrors recipe.py::recipes + only_<op>).
pub fn recipe(name: &str) -> Result<NativeRecipe> {
    let b = NativeRecipe::base(name);
    let r = match name {
        "bf16" => NativeRecipe { mode: QuantKind::Bf16, protect_last: 0, ..b },
        "fp8" => NativeRecipe { mode: QuantKind::Fp8, protect_last: 0, ..b },
        "nvfp4" => b,
        "chon" => NativeRecipe { hcp_frac: HCP_FRAC, post_qk: true, ..b },
        "chon_no_sr" => {
            NativeRecipe { sr: false, hcp_frac: HCP_FRAC, post_qk: true, ..b }
        }
        "chon_no_rht" => {
            NativeRecipe { rht: false, hcp_frac: HCP_FRAC, post_qk: true, ..b }
        }
        "chon_no_2d" => NativeRecipe {
            scaling_2d: false,
            hcp_frac: HCP_FRAC,
            post_qk: true,
            ..b
        },
        "chon_no_sr_rht" => NativeRecipe {
            sr: false,
            rht: false,
            hcp_frac: HCP_FRAC,
            post_qk: true,
            ..b
        },
        "chon_no_last4" => NativeRecipe {
            hcp_frac: HCP_FRAC,
            protect_last: 0,
            post_qk: true,
            ..b
        },
        "hcp_no_postqk_rht" => {
            NativeRecipe { rht: false, hcp_frac: HCP_FRAC, ..b }
        }
        "nvfp4_hcp" => NativeRecipe { hcp_frac: HCP_FRAC, ..b },
        other => {
            let Some(tag) = other.strip_prefix("only_") else {
                bail!("unknown recipe {other:?}");
            };
            // "only_attn_q" -> op "attn.q" (first '_' splits the group)
            let op = tag.replacen('_', ".", 1);
            NativeRecipe { protect_last: 0, only_op: Some(op), ..b }
        }
    };
    Ok(r)
}

/// The recipes the native backend ships, bf16 first (ablation ordering).
pub fn available_recipes() -> Vec<String> {
    [
        "bf16",
        "fp8",
        "nvfp4",
        "chon",
        "chon_no_sr",
        "chon_no_rht",
        "chon_no_2d",
        "chon_no_sr_rht",
        "chon_no_last4",
        "hcp_no_postqk_rht",
        "nvfp4_hcp",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Tab. 3 operator list for one architecture.
pub fn sensitivity_ops(arch: Arch) -> Vec<String> {
    let base = ["attn.q", "attn.k", "attn.v", "attn.o"];
    let gla = ["attn.gk", "attn.g"];
    let mlp = ["mlp.up", "mlp.gate", "mlp.down"];
    let mut ops: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    if arch == Arch::Gla {
        ops.extend(gla.iter().map(|s| s.to_string()));
    }
    ops.extend(mlp.iter().map(|s| s.to_string()));
    ops.sort();
    ops
}

/// Effective quantization of one operator in one layer.
#[derive(Clone, Debug)]
pub struct OpQuant {
    pub mode: QuantKind,
    pub scaling_2d: bool,
    pub sr: bool,
    pub rht: bool,
    pub hcp_frac: f64,
}

pub const BF16_OP: OpQuant = OpQuant {
    mode: QuantKind::Bf16,
    scaling_2d: false,
    sr: false,
    rht: false,
    hcp_frac: 0.0,
};

/// Post-QK sensitive operators per architecture (Tab. 3 / Fig. 2).
fn post_qk_protected(arch: Arch, op: &str) -> bool {
    match arch {
        Arch::Gla => op == "attn.o" || op == "attn.gk",
        Arch::Sa => op == "attn.v",
    }
}

/// Resolve the OpQuant for one linear operator (recipe.py::op_quant).
pub fn op_quant(
    r: &NativeRecipe,
    arch: Arch,
    layer: usize,
    n_layers: usize,
    op: &str,
) -> OpQuant {
    if let Some(target) = &r.only_op {
        // Tab. 3 sensitivity mode: exactly one quantized operator.
        if op != target {
            return BF16_OP;
        }
        return OpQuant {
            mode: r.mode,
            scaling_2d: r.scaling_2d,
            sr: r.sr,
            rht: r.rht,
            hcp_frac: r.hcp_frac,
        };
    }
    if r.mode == QuantKind::Bf16 {
        return BF16_OP;
    }
    if r.protect_last > 0 && layer + r.protect_last >= n_layers {
        return BF16_OP;
    }
    if r.post_qk && post_qk_protected(arch, op) {
        return BF16_OP;
    }
    OpQuant {
        mode: r.mode,
        scaling_2d: r.scaling_2d,
        sr: r.sr,
        rht: r.rht,
        hcp_frac: r.hcp_frac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_recipes_resolve() {
        for name in available_recipes() {
            let r = recipe(&name).unwrap();
            assert_eq!(r.name, name);
        }
        assert!(recipe("nope").is_err());
    }

    #[test]
    fn only_op_parses() {
        let r = recipe("only_attn_gk").unwrap();
        assert_eq!(r.only_op.as_deref(), Some("attn.gk"));
        let r = recipe("only_mlp_up").unwrap();
        assert_eq!(r.only_op.as_deref(), Some("mlp.up"));
    }

    #[test]
    fn chon_protects_post_qk_and_last_layer() {
        let r = recipe("chon").unwrap();
        // last layer protected
        let q = op_quant(&r, Arch::Gla, 1, 2, "mlp.up");
        assert_eq!(q.mode, QuantKind::Bf16);
        // post-QK ops protected even in quantized layers
        let q = op_quant(&r, Arch::Gla, 0, 2, "attn.gk");
        assert_eq!(q.mode, QuantKind::Bf16);
        let q = op_quant(&r, Arch::Sa, 0, 2, "attn.v");
        assert_eq!(q.mode, QuantKind::Bf16);
        // everything else NVFP4 + HCP
        let q = op_quant(&r, Arch::Gla, 0, 2, "mlp.up");
        assert_eq!(q.mode, QuantKind::Nvfp4);
        assert!(q.hcp_frac > 0.0);
    }

    #[test]
    fn nvfp4_quantizes_post_qk() {
        let r = recipe("nvfp4").unwrap();
        let q = op_quant(&r, Arch::Gla, 0, 2, "attn.gk");
        assert_eq!(q.mode, QuantKind::Nvfp4);
        assert_eq!(q.hcp_frac, 0.0);
    }

    #[test]
    fn only_op_quantizes_exactly_one() {
        let r = recipe("only_attn_q").unwrap();
        assert_eq!(op_quant(&r, Arch::Gla, 0, 2, "attn.q").mode, QuantKind::Nvfp4);
        assert_eq!(op_quant(&r, Arch::Gla, 1, 2, "attn.q").mode, QuantKind::Nvfp4);
        assert_eq!(op_quant(&r, Arch::Gla, 0, 2, "attn.k").mode, QuantKind::Bf16);
    }

    #[test]
    fn sensitivity_ops_cover_arches() {
        let gla = sensitivity_ops(Arch::Gla);
        assert!(gla.contains(&"attn.gk".to_string()));
        assert_eq!(gla.len(), 9);
        let sa = sensitivity_ops(Arch::Sa);
        assert!(!sa.contains(&"attn.gk".to_string()));
        assert_eq!(sa.len(), 7);
    }
}
