//! Data-parallel native training: shard the batch over the persistent
//! worker pool, run forward/backward per shard, allreduce gradients, then
//! apply one Adam update on the master copy.
//!
//! The decomposition is chosen so the *math* never depends on the shard
//! count: the reduction unit is one batch row (one sequence), whatever
//! `--shards N` says. Each unit runs `model::loss_and_grads` over its own
//! rows with its own RNG stream (`fold_in(unit)`), and unit results are
//! combined by a fixed-shape pairwise tree (stride doubling over unit
//! indices) — the same additions in the same order for every N. N only
//! decides how units are distributed across pool workers, so
//! `--shards 8` and `--shards 1` produce bit-identical loss trajectories
//! (the property `tests/shard_train.rs` pins down).
//!
//! This is also why the per-unit quantization scope differs from the
//! fused `model::train_step`: NVFP4 encode scaling is row-local either
//! way, but HCP hot-channel selection and RHT sign draws see one sequence
//! instead of the whole batch. That is a deliberate contract change —
//! batch-global quantization state is exactly what cannot be sharded
//! without making results depend on N.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::obs::train::{PhaseSpans, PH_ADAM, PH_ALLREDUCE, PH_FWD_BWD};
use crate::runtime::artifact::Manifest;
use crate::runtime::backend::{check_inputs, Executable};
use crate::runtime::native::model::{self, ModelCfg};
use crate::runtime::native::recipe::{self, NativeRecipe};
use crate::runtime::native::{build_manifest, parse_name, Kind};
use crate::runtime::tensor::HostTensor;
use crate::util::ndarray::Mat;
use crate::util::pool;
use crate::util::prng::Rng;

/// A train executable that runs the step data-parallel over the pool.
/// Speaks the exact train-artifact protocol of `NativeExec`, so the
/// `Trainer` drives it unchanged.
pub struct ShardExec {
    cfg: ModelCfg,
    recipe: NativeRecipe,
    manifest: Manifest,
    shards: usize,
    /// optional phase-span sink (fwd_bwd / allreduce / adam timings);
    /// timing only — the math is identical with or without it
    spans: Option<Arc<PhaseSpans>>,
}

impl ShardExec {
    /// `name` must be a `train_<model>_<recipe>` artifact name. `shards`
    /// is clamped to [1, batch] at run time (a shard needs at least one
    /// batch row).
    pub fn new(name: &str, shards: usize) -> Result<ShardExec> {
        let (kind, model_name, recipe_name) = parse_name(name)?;
        if kind != Kind::Train {
            bail!("ShardExec wraps train artifacts, got {name:?}");
        }
        let cfg = model::model_cfg(&model_name)?;
        let recipe_name =
            recipe_name.ok_or_else(|| anyhow::anyhow!("{name:?} names no recipe"))?;
        let rec = recipe::recipe(&recipe_name)?;
        let manifest = build_manifest(name, Kind::Train, &cfg, Some(&recipe_name));
        Ok(ShardExec {
            cfg,
            recipe: rec,
            manifest,
            shards: shards.max(1),
            spans: None,
        })
    }

    /// Attach a phase-span sink before the executable is frozen behind
    /// `Rc<dyn Executable>` (the trainer shares the same sink with its
    /// data-wait and diag-probe spans).
    pub fn with_spans(mut self, spans: Arc<PhaseSpans>) -> ShardExec {
        self.spans = Some(spans);
        self
    }
}

/// Fixed-shape pairwise tree reduction over per-unit (loss, grads):
/// stride doubling over unit indices, so the addition order is a function
/// of the unit count alone — never of the shard count or scheduling.
fn tree_reduce(mut slots: Vec<Option<(f32, Vec<Mat>)>>) -> (f32, Vec<Mat>) {
    let n = slots.len();
    assert!(n > 0);
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let rhs = slots[i + stride].take().expect("tree slot consumed twice");
            let lhs = slots[i].as_mut().expect("tree slot missing");
            lhs.0 += rhs.0;
            for (g, r) in lhs.1.iter_mut().zip(&rhs.1) {
                g.add_assign(r);
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    slots[0].take().expect("tree root missing")
}

impl Executable for ShardExec {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        check_inputs(&self.manifest, inputs)?;
        let specs = model::param_specs(&self.cfg);
        let k = specs.len();
        let step = inputs[3 * k].i32_data[0] as usize;
        let tokens = &inputs[3 * k + 1].i32_data;
        let targets = &inputs[3 * k + 2].i32_data;
        let seed = inputs[3 * k + 3].i32_data[0] as u64;
        let mut params = model::params_to_mats(&inputs[..k]);
        let mut m = model::params_to_mats(&inputs[k..2 * k]);
        let mut v = model::params_to_mats(&inputs[2 * k..3 * k]);

        let seq = self.cfg.seq;
        let units = tokens.len() / seq;
        debug_assert_eq!(tokens.len() % seq, 0);
        let shards = self.shards.clamp(1, units);
        let per = units.div_ceil(shards);

        // per-shard: forward/backward each owned unit at batch 1. The
        // unit math is shard-layout-independent; only scheduling varies.
        let cfg = &self.cfg;
        let rec = &self.recipe;
        let params_ref = &params;
        let t_fwd = Instant::now();
        let shard_results: Vec<Vec<(f32, Vec<Mat>)>> =
            pool::global().map(shards, |s| {
                let u0 = s * per;
                let u1 = ((s + 1) * per).min(units);
                (u0..u1)
                    .map(|u| {
                        let toks = &tokens[u * seq..(u + 1) * seq];
                        let tgts = &targets[u * seq..(u + 1) * seq];
                        let mut rng = Rng::new(seed ^ 0x5EED_0001)
                            .fold_in(step as u64)
                            .fold_in(u as u64);
                        model::loss_and_grads(cfg, rec, params_ref, toks, tgts, &mut rng)
                    })
                    .collect()
            });
        if let Some(sp) = &self.spans {
            sp.record_elapsed(PH_FWD_BWD, t_fwd.elapsed());
        }

        // deterministic allreduce: units in index order, fixed tree shape.
        // Peak memory holds one grad set per unit before the fold — fine
        // at tiny-model scale; eager folding of finished subtree pairs
        // would cut that without changing the bits if models grow.
        let t_reduce = Instant::now();
        let slots: Vec<Option<(f32, Vec<Mat>)>> = shard_results
            .into_iter()
            .flatten()
            .map(Some)
            .collect();
        debug_assert_eq!(slots.len(), units);
        let (loss_sum, mut grads) = tree_reduce(slots);
        let inv = 1.0f32 / units as f32;
        for g in grads.iter_mut() {
            for x in g.data.iter_mut() {
                *x *= inv;
            }
        }
        let loss = loss_sum * inv;
        if let Some(sp) = &self.spans {
            sp.record_elapsed(PH_ALLREDUCE, t_reduce.elapsed());
        }

        let t_adam = Instant::now();
        let lr = model::lr_at(step, self.cfg.total_steps);
        let gnorm = model::adam_update(&mut params, &mut m, &mut v, &grads, step, lr);
        if let Some(sp) = &self.spans {
            sp.record_elapsed(PH_ADAM, t_adam.elapsed());
        }

        let to_tensors = |mats: Vec<Mat>| -> Vec<HostTensor> {
            specs
                .iter()
                .zip(mats)
                .map(|(s, mat)| HostTensor::f32(s.shape.clone(), mat.data))
                .collect()
        };
        let mut out = to_tensors(params);
        out.extend(to_tensors(m));
        out.extend(to_tensors(v));
        out.push(HostTensor::scalar_f32(loss));
        out.push(HostTensor::scalar_f32(gnorm));
        out.push(HostTensor::scalar_f32(lr));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn train_inputs(cfg: &ModelCfg, seed: u64) -> Vec<HostTensor> {
        let params = model::init_params(cfg, seed);
        let k = params.len();
        let mut inputs = params.clone();
        for p in &params {
            inputs.push(HostTensor::zeros(p.dtype, p.shape.clone()));
        }
        for p in &params {
            inputs.push(HostTensor::zeros(p.dtype, p.shape.clone()));
        }
        inputs.push(HostTensor::scalar_i32(0));
        let (b, s) = (cfg.batch, cfg.seq);
        let mut rng = Rng::new(seed ^ 0xBA7C);
        let toks: Vec<i32> = (0..b * s + 1).map(|_| (rng.below(24) as i32) + 97).collect();
        inputs.push(HostTensor::i32(vec![b, s], toks[..b * s].to_vec()));
        inputs.push(HostTensor::i32(vec![b, s], toks[1..].to_vec()));
        inputs.push(HostTensor::scalar_i32(seed as i32));
        assert_eq!(inputs.len(), 3 * k + 4);
        inputs
    }

    #[test]
    fn shard_count_does_not_change_the_bits() {
        // the acceptance property at the executable level: any N in
        // [1, batch] (and beyond — clamped) produces identical outputs,
        // including under the full chon recipe (SR + RHT + HCP)
        let cfg = model::model_cfg("tiny_gla").unwrap();
        let inputs = train_inputs(&cfg, 11);
        let base = ShardExec::new("train_tiny_gla_chon", 1)
            .unwrap()
            .run(&inputs)
            .unwrap();
        for shards in [2, 3, 4, 16] {
            let out = ShardExec::new("train_tiny_gla_chon", shards)
                .unwrap()
                .run(&inputs)
                .unwrap();
            assert_eq!(base.len(), out.len());
            for (a, b) in base.iter().zip(&out) {
                assert_eq!(a.f32_data, b.f32_data, "shards={shards}");
            }
        }
    }

    #[test]
    fn sharded_step_descends_like_any_train_step() {
        let cfg = model::model_cfg("tiny_gla").unwrap();
        let exe = ShardExec::new("train_tiny_gla_bf16", 2).unwrap();
        let k = model::param_specs(&cfg).len();
        let mut inputs = train_inputs(&cfg, 5);
        let mut losses = Vec::new();
        for step in 0..12 {
            inputs[3 * k] = HostTensor::scalar_i32(step);
            let out = exe.run(&inputs).unwrap();
            losses.push(out[3 * k].f32_data[0]);
            // thread state (params, m, v) back in for the next step
            for (slot, t) in out.into_iter().take(3 * k).enumerate() {
                inputs[slot] = t;
            }
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses[11] < losses[0] - 0.5,
            "no descent: {} -> {}",
            losses[0],
            losses[11]
        );
    }

    #[test]
    fn rejects_non_train_artifacts() {
        assert!(ShardExec::new("init_tiny_gla", 2).is_err());
        assert!(ShardExec::new("diag_tiny_gla_chon", 2).is_err());
        assert!(ShardExec::new("train_tiny_gla_nope", 2).is_err());
    }
}
