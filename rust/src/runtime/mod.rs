//! The runtime layer: artifact manifests, host tensors, and the pluggable
//! execution engines behind the coordinator.
//!
//! * `backend` — the `Backend`/`Executable` traits + `backend_for` factory.
//! * `ckptdir` — checkpoint directories (params + optimizer + tokenizer +
//!   metadata), the train→serve interchange format.
//! * `native` — pure-Rust engine (default; offline, deterministic).
//! * `executable` — the PJRT/XLA engine (`--features pjrt`): HLO *text* is
//!   the interchange format (`HloModuleProto::from_text_file` ->
//!   `XlaComputation::from_proto` -> `PjRtClient::cpu().compile` ->
//!   `execute`; xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
//!   serialized protos).

pub mod artifact;
pub mod backend;
pub mod ckptdir;
#[cfg(feature = "pjrt")]
pub mod executable;
pub mod native;
pub mod tensor;

pub use artifact::Manifest;
pub use ckptdir::{CheckpointMeta, LoadedCheckpoint};
pub use backend::{backend_for, check_inputs, Backend, Executable};
#[cfg(feature = "pjrt")]
pub use executable::{client, LoadedArtifact, PjrtBackend};
pub use native::NativeBackend;
pub use tensor::{load_checkpoint, save_checkpoint, DType, HostTensor};
