//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Flow (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `PjRtClient::cpu().compile` ->
//! `execute`. HLO *text* is the interchange format (xla_extension 0.5.1
//! rejects jax>=0.5's 64-bit-id serialized protos).

pub mod artifact;
pub mod executable;
pub mod tensor;

pub use artifact::Manifest;
pub use executable::{client, LoadedArtifact};
pub use tensor::{load_checkpoint, save_checkpoint, DType, HostTensor};
