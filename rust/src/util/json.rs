//! Minimal JSON value + parser/serializer (the serde_json substitute —
//! DESIGN.md §Substitutions). Covers the subset the bench persistence
//! layer needs: objects, arrays, strings, finite numbers, bools, null.
//! Object key order is preserved (Vec of pairs, not a map) so emitted
//! files diff cleanly across runs.

use std::fmt::Write as _;

/// One JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    /// Serialize compactly (no whitespace). Named `render` rather than
    /// `to_string` so it cannot shadow a future Display impl.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation (the checked-in-file format).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {}", *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err("unexpected end of input".into());
    };
    match c {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {}", *pos));
                }
                *pos += 1;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    while let Some(&c) = b.get(*pos) {
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    return Err("unterminated escape".into());
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| "bad \\u escape".to_string())?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape".to_string())?;
                        *pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape \\{}", other as char)),
                }
            }
            c if c < 0x80 => out.push(c as char),
            _ => {
                // multi-byte UTF-8: copy the full sequence verbatim
                let start = *pos - 1;
                let len = match c {
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                if start + len > b.len() {
                    return Err("truncated UTF-8 in string".into());
                }
                let s = std::str::from_utf8(&b[start..start + len])
                    .map_err(|_| "invalid UTF-8 in string".to_string())?;
                out.push_str(s);
                *pos = start + len;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while let Some(&c) = b.get(*pos) {
        if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
            *pos += 1;
        } else {
            break;
        }
    }
    let s = std::str::from_utf8(&b[start..*pos]).unwrap();
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {s:?} at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::Obj(vec![
            ("version".into(), Json::Num(1.0)),
            ("name".into(), Json::Str("perf \"hot\"\npaths".into())),
            (
                "results".into(),
                Json::Arr(vec![
                    Json::Obj(vec![
                        ("name".into(), Json::Str("matmul".into())),
                        ("median_ms".into(), Json::Num(1.25)),
                    ]),
                    Json::Null,
                    Json::Bool(true),
                ]),
            ),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        for text in [doc.render(), doc.render_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), doc, "{text}");
        }
    }

    #[test]
    fn parses_numbers_and_unicode() {
        let v = Json::parse(r#"{"a": -1.5e3, "b": "café é"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("café é"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "{\"a\":}", "12x", "\"open", "{}extra", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors_are_typed() {
        let v = Json::parse(r#"{"n": 2, "s": "x", "l": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_f64(), Some(2.0));
        assert!(v.get("n").unwrap().as_str().is_none());
        assert_eq!(v.get("l").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
    }
}
