//! Deterministic PRNG substrate: splitmix64 seeding + xoshiro256++ core,
//! with the samplers the workload generators and benches need (uniform,
//! normal, Laplace, Student-t, Zipf, Rademacher).
//!
//! `rand`/`rand_distr` are not in the offline vendor set; this is the
//! documented substitution (DESIGN.md §Substitutions).

/// splitmix64: seed expander (reference implementation, Vigna 2015).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-layer keys).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut sm = self.s[0] ^ data.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached spare omitted for simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Laplace(0, b) via inverse CDF.
    pub fn laplace(&mut self, b: f32) -> f32 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-12).ln()
    }

    /// Student-t with `dof` degrees of freedom (heavy-tail generator):
    /// t = Z / sqrt(ChiSq_dof / dof), ChiSq via sum of dof squared normals.
    pub fn student_t(&mut self, dof: u32) -> f32 {
        let z = self.normal();
        let mut chi = 0.0f32;
        for _ in 0..dof {
            let n = self.normal();
            chi += n * n;
        }
        z / (chi / dof as f32).sqrt().max(1e-6)
    }

    /// Rademacher ±1.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with normals scaled by `scale`.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }
}

/// Zipf(s) sampler over [0, n) using precomputed CDF (corpus substrate).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform() as f64;
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_in_changes_stream() {
        let base = Rng::new(7);
        let mut a = base.fold_in(1);
        let mut b = base.fold_in(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut s2 = 0.0f64;
        for _ in 0..n {
            let x = r.laplace(1.0) as f64;
            s2 += x * x;
        }
        let var = s2 / n as f64; // Laplace(0,1) variance = 2
        assert!((var - 2.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn student_t_heavier_than_normal() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let kurt = |xs: &[f32]| {
            let m = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>()
                / xs.len() as f64;
            let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>()
                / xs.len() as f64;
            m4 / (v * v) - 3.0
        };
        let t: Vec<f32> = (0..n).map(|_| r.student_t(5)).collect();
        let g: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        assert!(kurt(&t) > kurt(&g) + 1.0);
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(100, 1.2);
        let mut r = Rng::new(5);
        let mut counts = [0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }
}
