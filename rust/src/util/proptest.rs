//! Mini property-test harness (the offline substitute for the proptest
//! crate — DESIGN.md §Substitutions).
//!
//! `check` runs a property over N generated cases and, on failure, greedily
//! shrinks the failing input via the generator's `shrink` hook before
//! panicking with the minimized counterexample.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// A generator of random test inputs with optional shrinking.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs. Panics with the (shrunk)
/// counterexample on failure.
pub fn check<G: Gen>(name: &str, seed: u64, cases: usize, gen: &G,
                     prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed ^ 0x70707070);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if prop(&v) {
            continue;
        }
        // shrink loop: first failing candidate wins, repeat to fixpoint
        let mut cur = v;
        let mut budget = 200;
        'outer: while budget > 0 {
            for cand in gen.shrink(&cur) {
                budget -= 1;
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property {name:?} failed at case {case} with (shrunk) input: {cur:?}"
        );
    }
}

/// Generator: f32 vector with length a multiple of `quantum`, values from
/// a mixture of gaussian / heavy-tail / spiky distributions.
pub struct VecGen {
    pub min_blocks: usize,
    pub max_blocks: usize,
    pub quantum: usize,
    pub scale: f32,
}

impl Gen for VecGen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let blocks = self.min_blocks + rng.below(self.max_blocks - self.min_blocks + 1);
        let n = blocks * self.quantum;
        let style = rng.below(4);
        let mut v: Vec<f32> = (0..n)
            .map(|_| match style {
                0 => rng.normal() * self.scale,
                1 => rng.laplace(self.scale),
                2 => rng.student_t(3) * self.scale,
                _ => rng.uniform_in(-self.scale, self.scale),
            })
            .collect();
        // occasionally plant an extreme outlier (the paper's regime)
        if rng.uniform() < 0.3 && !v.is_empty() {
            let i = rng.below(v.len());
            v[i] = self.scale * 300.0 * rng.sign();
        }
        v
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        // halve the vector (keeping quantum alignment)
        if v.len() > self.quantum {
            let half = (v.len() / 2 / self.quantum).max(1) * self.quantum;
            out.push(v[..half].to_vec());
            out.push(v[v.len() - half..].to_vec());
        }
        // zero out halves of the values
        if v.iter().any(|&x| x != 0.0) {
            let mut a = v.clone();
            for x in a.iter_mut().take(v.len() / 2) {
                *x = 0.0;
            }
            out.push(a);
            let mut b = v.clone();
            for x in b.iter_mut().skip(v.len() / 2) {
                *x = 0.0;
            }
            out.push(b);
        }
        out
    }
}

/// Generator: usize in [lo, hi] with halving shrink.
pub struct RangeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for RangeGen {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
        }
        out
    }
}

/// Pair generator combinator.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", 1, 50, &RangeGen { lo: 0, hi: 100 }, |_| true);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check("always-false", 2, 10, &RangeGen { lo: 0, hi: 100 }, |_| false);
    }

    #[test]
    #[should_panic]
    fn shrinks_toward_minimum() {
        // fails for v >= 10; shrinking should not mask the failure
        check("ge10", 3, 100, &RangeGen { lo: 0, hi: 100 }, |&v| v < 10);
    }

    #[test]
    fn vecgen_respects_quantum() {
        let g = VecGen { min_blocks: 1, max_blocks: 5, quantum: 16, scale: 1.0 };
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let v = g.generate(&mut rng);
            assert_eq!(v.len() % 16, 0);
            assert!(!v.is_empty());
        }
    }

    #[test]
    fn vecgen_shrink_preserves_quantum() {
        let g = VecGen { min_blocks: 1, max_blocks: 5, quantum: 16, scale: 1.0 };
        let mut rng = Rng::new(5);
        let v = g.generate(&mut rng);
        for s in g.shrink(&v) {
            assert_eq!(s.len() % 16, 0);
        }
    }
}
