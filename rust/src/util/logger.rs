//! Minimal stderr logger (the `log` + `env_logger` substitute — neither
//! crate is in the offline vendor set). Level via
//! CHON_LOG=error|warn|info|debug|trace (default info); output format via
//! CHON_LOG_FORMAT=human|json (default human).
//!
//! Call sites use the crate-level `error!` / `warn!` / `info!` /
//! `debug!` / `trace!` macros, which mirror the `log` facade's
//! formatting surface. Each record carries a monotonic elapsed-seconds
//! timestamp (relative to the first record, so lines correlate with
//! latency numbers without wall-clock parsing) and the emitting module
//! path as its target:
//!
//! ```text
//! [I +12.042s chon::serve::server] serving 2 model(s) on port 7411
//! ```
//!
//! With `CHON_LOG_FORMAT=json` each record is one JSON object per line
//! (`{"ts":12.042,"level":"info","target":"...","msg":"..."}`), for log
//! shippers that want structured input.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ascending verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Record output format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Human,
    Json,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static FORMAT: AtomicU8 = AtomicU8::new(0); // 0 = human, 1 = json

/// The monotonic epoch of the `ts` field: set once on the first record
/// (or the first explicit query), so elapsed timestamps start near 0.
fn epoch() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the maximum level that will be emitted.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Set the record output format.
pub fn set_format(format: Format) {
    FORMAT.store(matches!(format, Format::Json) as u8, Ordering::Relaxed);
}

/// Whether `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Render one record without emitting it (the pure core, unit-testable).
/// `elapsed_s` is seconds since the process's first record.
pub fn format_record(
    level: Level,
    target: &str,
    elapsed_s: f64,
    msg: &str,
    format: Format,
) -> String {
    match format {
        Format::Human => {
            format!("[{} +{elapsed_s:.3}s {target}] {msg}", level.tag())
        }
        Format::Json => crate::util::json::Json::Obj(vec![
            ("ts".into(), crate::util::json::Json::Num(elapsed_s)),
            (
                "level".into(),
                crate::util::json::Json::Str(level.name().into()),
            ),
            ("target".into(), crate::util::json::Json::Str(target.into())),
            ("msg".into(), crate::util::json::Json::Str(msg.into())),
        ])
        .render(),
    }
}

/// Emit one record (used by the macros; callable directly too).
/// `target` is the emitting module path (the macros pass
/// `module_path!()`).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let elapsed = epoch().elapsed().as_secs_f64();
    // round to ms so the ts field is stable-width and diff-friendly
    let elapsed = (elapsed * 1e3).round() / 1e3;
    let format = if FORMAT.load(Ordering::Relaxed) == 1 {
        Format::Json
    } else {
        Format::Human
    };
    eprintln!(
        "{}",
        format_record(level, target, elapsed, &args.to_string(), format)
    );
}

/// Install the level from CHON_LOG and the format from CHON_LOG_FORMAT
/// (idempotent; defaults info + human).
pub fn init() {
    let level = match std::env::var("CHON_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
    let format = match std::env::var("CHON_LOG_FORMAT").as_deref() {
        Ok("json") => Format::Json,
        _ => Format::Human,
    };
    set_format(format);
    epoch(); // pin ts=0 at init, not at the first record
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test: MAX_LEVEL is process-global, so splitting these
    // into parallel #[test]s would race on it, and asserting the level
    // after init() would depend on the CHON_LOG env var.
    #[test]
    fn init_and_level_gating() {
        init();
        init(); // idempotent
        crate::info!("logger smoke {}", 1);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore the default
        assert!(enabled(Level::Info));
    }

    #[test]
    fn human_format_has_tag_elapsed_and_target() {
        let line = format_record(
            Level::Info,
            "chon::serve::server",
            12.0424,
            "serving on 7411",
            Format::Human,
        );
        assert_eq!(line, "[I +12.042s chon::serve::server] serving on 7411");
        let line =
            format_record(Level::Error, "chon::a", 0.0, "boom", Format::Human);
        assert_eq!(line, "[E +0.000s chon::a] boom");
    }

    #[test]
    fn json_format_is_one_escaped_object() {
        let line = format_record(
            Level::Warn,
            "chon::util",
            1.5,
            "a \"quoted\"\nline",
            Format::Json,
        );
        assert_eq!(
            line,
            "{\"ts\":1.5,\"level\":\"warn\",\"target\":\"chon::util\",\
             \"msg\":\"a \\\"quoted\\\"\\nline\"}"
        );
        // round-trips through the crate's own JSON parser
        let doc = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(doc.get("level").and_then(|v| v.as_str()), Some("warn"));
        assert_eq!(
            doc.get("msg").and_then(|v| v.as_str()),
            Some("a \"quoted\"\nline")
        );
        assert_eq!(doc.get("ts").and_then(|v| v.as_f64()), Some(1.5));
    }
}
