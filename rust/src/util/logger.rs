//! Minimal stderr logger (the `log` + `env_logger` substitute — neither
//! crate is in the offline vendor set). Level via
//! CHON_LOG=error|warn|info|debug|trace (default info).
//!
//! Call sites use the crate-level `error!` / `warn!` / `info!` /
//! `debug!` / `trace!` macros, which mirror the `log` facade's
//! formatting surface.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ascending verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the maximum level that will be emitted.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (used by the macros; callable directly too).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Error => "E",
        Level::Warn => "W",
        Level::Info => "I",
        Level::Debug => "D",
        Level::Trace => "T",
    };
    eprintln!("[{tag}] {args}");
}

/// Install the level from CHON_LOG (idempotent; default info).
pub fn init() {
    let level = match std::env::var("CHON_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test: MAX_LEVEL is process-global, so splitting these
    // into parallel #[test]s would race on it, and asserting the level
    // after init() would depend on the CHON_LOG env var.
    #[test]
    fn init_and_level_gating() {
        init();
        init(); // idempotent
        crate::info!("logger smoke {}", 1);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore the default
        assert!(enabled(Level::Info));
    }
}
