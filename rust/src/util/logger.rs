//! Minimal stderr logger backing the `log` facade (env_logger substitute).
//! Level via CHON_LOG=error|warn|info|debug|trace (default info).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        eprintln!("[{tag}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("CHON_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
