//! Persistent worker pool: parked `std::thread` workers with scoped task
//! submission — the per-call `std::thread::scope` spawn that used to sit
//! on the matmul hot path is gone; workers are created once and reused by
//! every `matmul_par` call, the data-parallel shard engine, and the serve
//! decode batch.
//!
//! Model: one job at a time, claim-based participation. A job is a task
//! counter plus a borrowed closure; the submitter posts it with a claim
//! budget of `min(workers, tasks - 1)` and wakes that many workers, each
//! of which *claims* a slot under the lock before touching the job, then
//! races on an atomic index until the counter is exhausted. The
//! submitting thread participates too (so a pool of W workers runs W+1
//! lanes, and `threads == 1` degrades to plain serial execution with no
//! synchronization at all), and it drains the queue regardless of how
//! many workers actually wake — a lost wakeup or a shut-down pool only
//! costs helpers, never completion. The submitter blocks until the claim
//! window is closed and every *claimed* worker has left the job (not
//! until the whole pool has cycled — a 4-task job on a 64-lane pool
//! wakes 3 workers and waits on at most 3), which is what makes
//! borrowing non-'static closures sound: the lifetime is erased for the
//! trip through the worker threads, but the borrow provably outlives the
//! job because `run` does not return (even on panic — a drop guard
//! closes the claim window and waits) while any worker can still touch
//! it.
//!
//! Determinism: the pool assigns *which thread* runs a task dynamically,
//! but callers only ever hand it tasks that write disjoint outputs and
//! whose per-task math is scheduling-independent. Every consumer in this
//! crate (row bands of the packed matmul, per-sequence grad shards, per-
//! session decode states) has that shape, so results are bit-identical at
//! any pool size — the property the `matmul_par == matmul` and
//! `--shards N == --shards 1` tests pin down.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Highest worker count the global pool will start (sanity clamp for
/// absurd CHON_THREADS values; the workers park when idle, but each one
/// still costs a stack).
const MAX_THREADS: usize = 256;

/// A lifetime-erased view of one submitted job. The pointers borrow from
/// the `run` call frame; the claim window being closed with
/// `State::inflight` at zero is the proof that no worker still holds (or
/// can still obtain) them.
#[derive(Clone, Copy)]
struct Job {
    /// the task closure, as a raw wide pointer to `dyn Fn(usize) + Sync`
    f: *const (dyn Fn(usize) + Sync),
    /// next task index to claim
    next: *const AtomicUsize,
    /// total number of tasks in the job
    total: usize,
}

// Job only crosses threads while `run` blocks on the same-frame borrow.
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    /// bumped once per submitted job so a worker never re-enters a job it
    /// already finished
    epoch: u64,
    /// how many more workers may still join the current job. Workers
    /// *claim* participation under the lock; the submitter never waits on
    /// workers that did not claim, so a lost wakeup (or a pool that was
    /// shut down) just means fewer helpers — the submitter drains the
    /// task queue itself either way.
    claim_left: usize,
    /// workers that claimed and have not finished yet
    inflight: usize,
    /// a worker task panicked (re-raised on the submitting thread)
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here waiting for a new epoch
    work_cv: Condvar,
    /// the submitter parks here waiting for `inflight == 0`
    done_cv: Condvar,
    /// serializes submitters: the pool broadcasts one job at a time, and
    /// e.g. `cargo test`'s parallel test threads all share the global
    /// pool. Nested submissions never touch this lock (they run inline).
    submit: Mutex<()>,
}

/// The persistent pool. One global instance (`global()`) serves the whole
/// process; tests construct private ones.
pub struct ThreadPool {
    shared: &'static Shared,
    workers: usize,
}

thread_local! {
    /// Set while this thread is executing pool tasks (worker or
    /// participating submitter). A nested `run` from inside a task would
    /// deadlock waiting for workers that are busy running *us*, so nested
    /// calls execute serially instead — same math, same bits.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// Closes the claim window and waits for in-flight workers on drop, so
/// neither a normal return nor a panic on the submitting thread can free
/// the borrowed closure while a worker still runs (or could still claim)
/// it.
struct WaitGuard<'a> {
    shared: &'a Shared,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        // no new claims: a worker waking late finds no job and re-parks
        st.claim_left = 0;
        st.job = None;
        while st.inflight > 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

fn worker_loop(shared: &'static Shared) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.job {
                    if st.epoch != seen_epoch && st.claim_left > 0 {
                        // claim participation (atomically with the job
                        // read — the submitter's wait covers exactly the
                        // claimed workers)
                        seen_epoch = st.epoch;
                        st.claim_left -= 1;
                        st.inflight += 1;
                        break j;
                    }
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let res = catch_unwind(AssertUnwindSafe(|| {
            IN_POOL_TASK.with(|f| f.set(true));
            let func = unsafe { &*job.f };
            let next = unsafe { &*job.next };
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= job.total {
                    break;
                }
                func(i);
            }
        }));
        IN_POOL_TASK.with(|f| f.set(false));
        let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
        if res.is_err() {
            st.panicked = true;
        }
        st.inflight -= 1;
        if st.inflight == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl ThreadPool {
    /// Start a pool running `threads` lanes total (`threads - 1` parked
    /// workers; the submitter is the extra lane). `threads <= 1` builds a
    /// pool with no workers that runs everything inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.clamp(1, MAX_THREADS);
        let workers = threads - 1;
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State {
                job: None,
                epoch: 0,
                claim_left: 0,
                inflight: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        }));
        for _ in 0..workers {
            std::thread::Builder::new()
                .name("chon-pool".into())
                .spawn(move || worker_loop(shared))
                .expect("spawning pool worker");
        }
        ThreadPool { shared, workers }
    }

    /// Total parallel lanes (workers + the participating submitter).
    pub fn lanes(&self) -> usize {
        self.workers + 1
    }

    /// Run `total` tasks, `f(i)` for each `i in 0..total`, across the
    /// pool + the calling thread. Blocks until every task has finished.
    /// Tasks must write disjoint data; the index→thread assignment is
    /// dynamic. Panics (on this thread) if any task panicked.
    pub fn run<F: Fn(usize) + Sync>(&self, total: usize, f: F) {
        if total == 0 {
            return;
        }
        // no workers, a single task, or a nested call from inside a pool
        // task: execute inline (nested submission would deadlock on the
        // busy workers, and the math is scheduling-independent anyway)
        if self.workers == 0 || total == 1 || IN_POOL_TASK.with(|c| c.get()) {
            for i in 0..total {
                f(i);
            }
            return;
        }
        // one job at a time; declared first so it drops after the
        // panicked-flag read below
        let _submit = self
            .shared
            .submit
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let next = AtomicUsize::new(0);
        let f_obj: &(dyn Fn(usize) + Sync) = &f;
        // erase the borrow's lifetime for the trip through the workers;
        // WaitGuard keeps this frame alive until every worker has left
        // the job, so the 'static claim is never acted on after free
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_obj) };
        let job = Job {
            f: f_static as *const (dyn Fn(usize) + Sync),
            next: &next as *const AtomicUsize,
            total,
        };
        // wake at most as many workers as there are tasks beyond the
        // submitter's own lane — a tiny job on a big pool must not pay a
        // full-pool wakeup-and-barrier round trip
        let helpers = self.workers.min(total - 1);
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            if st.shutdown {
                // workers are gone; degrade to inline instead of waiting
                // on claims that can never come
                drop(st);
                for i in 0..total {
                    f(i);
                }
                return;
            }
            st.job = Some(job);
            st.epoch = st.epoch.wrapping_add(1);
            st.claim_left = helpers;
            st.inflight = 0;
            st.panicked = false;
        }
        // notify_one per wanted helper: a lost wakeup only costs a helper
        // (the submitter drains the queue regardless), never correctness
        for _ in 0..helpers {
            self.shared.work_cv.notify_one();
        }
        let guard = WaitGuard { shared: self.shared };
        // participate: the submitting thread is one of the lanes
        IN_POOL_TASK.with(|c| c.set(true));
        let res = catch_unwind(AssertUnwindSafe(|| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= total {
                break;
            }
            f(i);
        }));
        IN_POOL_TASK.with(|c| c.set(false));
        drop(guard); // waits for the workers
        let panicked = {
            let st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.panicked
        };
        if let Err(p) = res {
            std::panic::resume_unwind(p);
        }
        if panicked {
            panic!("a pool task panicked");
        }
    }

    /// `f(i, &mut items[i])` in parallel — disjoint `&mut` access to the
    /// slice elements without locks.
    pub fn for_each_mut<T: Send, F: Fn(usize, &mut T) + Sync>(
        &self,
        items: &mut [T],
        f: F,
    ) {
        struct SendPtr<T>(*mut T);
        unsafe impl<T> Send for SendPtr<T> {}
        unsafe impl<T> Sync for SendPtr<T> {}
        let base = SendPtr(items.as_mut_ptr());
        let n = items.len();
        self.run(n, move |i| {
            // each index visited exactly once -> disjoint &mut
            let item = unsafe { &mut *base.0.add(i) };
            f(i, item);
        });
    }

    /// Collect `f(i)` for `i in 0..n`, in index order.
    pub fn map<T: Send, F: Fn(usize) -> T + Sync>(&self, n: usize, f: F) -> Vec<T> {
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        self.for_each_mut(&mut slots, |i, slot| {
            *slot = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.expect("pool task did not fill its slot"))
            .collect()
    }

    /// Ask the workers to exit (tests; the global pool never shuts down).
    pub fn shutdown(&self) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.shutdown = true;
        self.shared.work_cv.notify_all();
    }
}

// ------------------------------------------------------------------
// The global pool
// ------------------------------------------------------------------

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// Set the lane count the global pool will start with. Must run before
/// the first `global()` call to take effect (main wires `--threads` here
/// before any compute); later calls are ignored. `CHON_THREADS` overrides
/// both.
pub fn configure_threads(threads: usize) {
    CONFIGURED.store(threads, Ordering::Relaxed);
}

fn default_threads() -> usize {
    if let Ok(v) = std::env::var("CHON_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    let hint = CONFIGURED.load(Ordering::Relaxed);
    if hint > 0 {
        return hint;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// The process-wide pool, started on first use.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        pool.shutdown();
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.run(16, |i| {
                sum.fetch_add(round + i as u64, Ordering::Relaxed);
            });
        }
        let expect: u64 = (0..50u64).map(|r| 16 * r + 120).sum();
        assert_eq!(sum.load(Ordering::Relaxed), expect);
        pool.shutdown();
    }

    #[test]
    fn map_returns_in_index_order() {
        let pool = ThreadPool::new(4);
        let v = pool.map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn for_each_mut_gives_disjoint_access() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<usize> = vec![0; 257];
        pool.for_each_mut(&mut items, |i, x| *x = i + 1);
        assert!(items.iter().enumerate().all(|(i, &x)| x == i + 1));
        pool.shutdown();
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.lanes(), 1);
        let v = pool.map(10, |i| i);
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn nested_run_from_a_task_completes() {
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, |_| {
            // would deadlock without the nested-serial fallback
            global().run(4, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 16);
        pool.shutdown();
    }

    #[test]
    fn run_after_shutdown_degrades_to_inline() {
        let pool = ThreadPool::new(4);
        pool.shutdown();
        // workers are gone; the submitter must drain everything itself
        // and return (this used to be a deadlock shape)
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must reach the submitter");
        // the pool must still work afterwards
        let v = pool.map(8, |i| i + 1);
        assert_eq!(v, (1..=8).collect::<Vec<_>>());
        pool.shutdown();
    }
}
