//! Minimal dense 2-D f32 tensor + packed SIMD-friendly matmul.
//!
//! The offline vendor set has no ndarray/nalgebra/rayon; this is the small
//! substrate the HCP pipeline, diagnostics and benches run on.
//!
//! The GEMM is a BLIS-style packed microkernel: B is packed once per call
//! into NR-wide, KC-blocked panels (reused across the whole k loop and
//! shared read-only across threads), the A row band is packed tile-major,
//! and an MR×NR register-tiled inner kernel accumulates over the full
//! contraction in fixed-size arrays the compiler autovectorizes. Each
//! output row's accumulation chain runs over k in ascending order and
//! touches only that row's operands, so results are bit-identical however
//! rows are tiled or banded — which is what lets `matmul_par` (row bands
//! on the persistent `util::pool` workers, no per-call spawn) promise
//! bitwise equality with `matmul` at every thread count.

use std::fmt;

use crate::util::pool;

/// Row-major (rows x cols) f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Cache-blocked transpose (TB×TB tiles): every backward GEMM in the
    /// native model transposes an operand, and the naive strided scatter
    /// missed cache on one side for any matrix wider than a cache line.
    pub fn transpose(&self) -> Mat {
        const TB: usize = 32;
        let (rows, cols) = (self.rows, self.cols);
        let mut out = Mat::zeros(cols, rows);
        for rb in (0..rows).step_by(TB) {
            let rend = (rb + TB).min(rows);
            for cb in (0..cols).step_by(TB) {
                let cend = (cb + TB).min(cols);
                for r in rb..rend {
                    let src = &self.data[r * cols..r * cols + cols];
                    for c in cb..cend {
                        out.data[c * rows + r] = src[c];
                    }
                }
            }
        }
        out
    }

    /// Gather the given columns into a new (rows x idx.len()) matrix.
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// Gather the given rows into a new (idx.len() x cols) matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (j, &r) in idx.iter().enumerate() {
            out.row_mut(j).copy_from_slice(self.row(r));
        }
        out
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Vertical concatenation [self ; other].
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn mse(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }
}

// ------------------------------------------------------------------
// Packed GEMM microkernel
// ------------------------------------------------------------------

/// Register-tile rows of the microkernel.
const MR: usize = 4;
/// Register-tile columns (two 8-lane f32 vectors on AVX2). Public: the
/// NVFP4 panel codec (`quant::nvfp4::PackedQuantMat`) lays its codes out
/// in this panel width so the quantized kernel decodes in panel order.
pub const NR: usize = 16;
/// Contraction block: one packed B panel block (KC×NR) stays L1-resident.
/// Public for the same reason as [`NR`].
pub const KC: usize = 256;
/// Row count below which the unpacked fallback wins (packing B costs
/// O(k·n), amortized over m rows — serve's batch-row GEMMs sit here).
const SMALL_M: usize = 8;

/// One KC-slice of the packed B operand.
struct PackedBlock {
    /// first contraction index of the slice
    k0: usize,
    /// slice depth (== KC except the ragged tail)
    kc: usize,
    /// offset of the slice's panels in `PackedB::data`
    off: usize,
}

/// B packed panel-wise: for each KC block, `npanels` panels of `kc` rows ×
/// NR columns, zero-padded to NR on the ragged right edge. Packed once per
/// GEMM and shared read-only by every row-band task.
struct PackedB {
    n: usize,
    npanels: usize,
    blocks: Vec<PackedBlock>,
    data: Vec<f32>,
}

fn pack_b(b: &Mat) -> PackedB {
    let (k, n) = (b.rows, b.cols);
    let npanels = n.div_ceil(NR);
    let mut data = vec![0.0f32; k * npanels * NR];
    let mut blocks = Vec::with_capacity(k.div_ceil(KC.max(1)).max(1));
    let mut off = 0usize;
    for k0 in (0..k).step_by(KC) {
        let kc = (k - k0).min(KC);
        for p in 0..npanels {
            let c0 = p * NR;
            let ncols = (n - c0).min(NR);
            let pbase = off + p * kc * NR;
            for kk in 0..kc {
                let src = &b.data[(k0 + kk) * n + c0..(k0 + kk) * n + c0 + ncols];
                data[pbase + kk * NR..pbase + kk * NR + ncols].copy_from_slice(src);
            }
        }
        blocks.push(PackedBlock { k0, kc, off });
        off += kc * npanels * NR;
    }
    PackedB { n, npanels, blocks, data }
}

/// Compute rows `r0..r0+nrows` of `a * packed-B` into `chunk` (row-major,
/// `packed.n` columns). The A band is packed tile-major first so the
/// inner loop reads both operands at unit stride; the MR×NR accumulator
/// lives in fixed-size arrays the compiler keeps in vector registers.
fn kernel_rows(
    a: &Mat,
    packed: &PackedB,
    r0: usize,
    nrows: usize,
    chunk: &mut [f32],
    accumulate: bool,
) {
    let k = a.cols;
    let n = packed.n;
    debug_assert_eq!(chunk.len(), nrows * n);
    let ntiles = nrows.div_ceil(MR);
    // A band, tile-major: apk[tile*k*MR + kk*MR + r] = a[r0+tile*MR+r, kk]
    // (rows past the edge stay zero — they add 0 to the accumulator and
    // are masked out of the write-back)
    let mut apk = vec![0.0f32; ntiles * k * MR];
    for t in 0..ntiles {
        let tbase = t * k * MR;
        let mr = (nrows - t * MR).min(MR);
        for r in 0..mr {
            let arow = a.row(r0 + t * MR + r);
            for (kk, &v) in arow.iter().enumerate() {
                apk[tbase + kk * MR + r] = v;
            }
        }
    }
    for t in 0..ntiles {
        let tbase = t * k * MR;
        let mr = (nrows - t * MR).min(MR);
        for p in 0..packed.npanels {
            let mut acc = [[0.0f32; NR]; MR];
            for blk in &packed.blocks {
                let at = &apk[tbase + blk.k0 * MR..tbase + (blk.k0 + blk.kc) * MR];
                let pb = blk.off + p * blk.kc * NR;
                let bp = &packed.data[pb..pb + blk.kc * NR];
                for kk in 0..blk.kc {
                    let av = &at[kk * MR..kk * MR + MR];
                    let bv = &bp[kk * NR..kk * NR + NR];
                    for r in 0..MR {
                        let ar = av[r];
                        let accr = &mut acc[r];
                        for j in 0..NR {
                            accr[j] += ar * bv[j];
                        }
                    }
                }
            }
            let c0 = p * NR;
            let ncols = (n - c0).min(NR);
            for r in 0..mr {
                let obase = (t * MR + r) * n + c0;
                let orow = &mut chunk[obase..obase + ncols];
                if accumulate {
                    for j in 0..ncols {
                        orow[j] += acc[r][j];
                    }
                } else {
                    orow[..ncols].copy_from_slice(&acc[r][..ncols]);
                }
            }
        }
    }
}

/// Unpacked fallback for short A (serve decode batches, vector-matrix):
/// k-inner loop over full B rows, n-innermost autovectorized.
fn matmul_small(a: &Mat, b: &Mat, out: &mut Mat, accumulate: bool) {
    let n = b.cols;
    if !accumulate {
        out.data.fill(0.0);
    }
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// A `Mat` pre-packed into the GEMM's B-panel layout, reusable across
/// any number of `matmul_packed` calls — the B-panel cache for weights
/// that never change between GEMMs (frozen serve weights). Packing is
/// the exact `pack_b` every `matmul` call runs internally, so consuming
/// a `PackedMat` is **bitwise identical** to multiplying the original
/// matrix: each output element's accumulation chain still runs over k in
/// ascending order with the same operand values (`tests/matmul_kernel.rs`
/// pins this across the small-m and packed-kernel regimes).
pub struct PackedMat {
    /// contraction depth (rows of the original B)
    k: usize,
    pb: PackedB,
}

impl fmt::Debug for PackedMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PackedMat({}x{})", self.k, self.pb.n)
    }
}

impl PackedMat {
    /// Pack `b` once for repeated use as a GEMM right-hand side.
    pub fn pack(b: &Mat) -> PackedMat {
        PackedMat { k: b.rows, pb: pack_b(b) }
    }

    /// Rows of the original matrix (the contraction depth).
    pub fn rows(&self) -> usize {
        self.k
    }

    /// Columns of the original matrix.
    pub fn cols(&self) -> usize {
        self.pb.n
    }

    /// Packed storage footprint in f32 elements (reporting).
    pub fn packed_len(&self) -> usize {
        self.pb.data.len()
    }
}

/// Small-m kernel over pre-packed B panels: per output row, panels are
/// walked with an NR-wide register accumulator. Every output element's
/// chain adds `a[i,kk] * b[kk,j]` for kk ascending (blocks are ascending,
/// kk ascending within a block), which is exactly `matmul_small`'s chain
/// — so this path is bitwise identical to the unpacked fallback while
/// reading B from the panel cache instead of re-walking the row-major
/// matrix.
fn kernel_rows_prepacked_small(a: &Mat, packed: &PackedB, out: &mut [f32]) {
    let n = packed.n;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..packed.npanels {
            let c0 = p * NR;
            let ncols = (n - c0).min(NR);
            let mut acc = [0.0f32; NR];
            for blk in &packed.blocks {
                let pbase = blk.off + p * blk.kc * NR;
                let bp = &packed.data[pbase..pbase + blk.kc * NR];
                for kk in 0..blk.kc {
                    let av = arow[blk.k0 + kk];
                    let bv = &bp[kk * NR..kk * NR + NR];
                    for j in 0..NR {
                        acc[j] += av * bv[j];
                    }
                }
            }
            orow[c0..c0 + ncols].copy_from_slice(&acc[..ncols]);
        }
    }
}

/// out = a (m x k) * packed-B (k x n), skipping the per-call `pack_b`.
/// Dispatches on the same `SMALL_M` threshold as `matmul`, and both
/// regimes build identical per-element accumulation chains, so the
/// result is bitwise equal to `matmul(a, b)` for the `b` that was
/// packed.
pub fn matmul_packed(a: &Mat, b: &PackedMat) -> Mat {
    assert_eq!(a.cols, b.k);
    let mut out = Mat::zeros(a.rows, b.pb.n);
    if a.rows == 0 || b.pb.n == 0 || a.cols == 0 {
        return out;
    }
    if a.rows < SMALL_M {
        kernel_rows_prepacked_small(a, &b.pb, &mut out.data);
    } else {
        kernel_rows(a, &b.pb, 0, a.rows, &mut out.data, false);
    }
    out
}

/// Packed single-threaded matmul: out = a (m x k) * b (k x n).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut out, false);
    out
}

/// out (+)= a * b; `accumulate` keeps existing contents.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    if a.rows == 0 || b.cols == 0 {
        return;
    }
    if a.cols == 0 {
        if !accumulate {
            out.data.fill(0.0);
        }
        return;
    }
    if a.rows < SMALL_M {
        matmul_small(a, b, out, accumulate);
        return;
    }
    let packed = pack_b(b);
    kernel_rows(a, &packed, 0, a.rows, &mut out.data, accumulate);
}

/// Multi-threaded matmul: MR-aligned row bands on the persistent worker
/// pool (`util::pool`) — no per-call thread spawn. Bit-identical to
/// `matmul` at every `threads` value: a band boundary never changes any
/// single row's accumulation chain.
pub fn matmul_par(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows);
    let t = threads.max(1).min(a.rows.max(1));
    // same threshold as matmul_into's small-m dispatch, so the serial and
    // parallel entry points always agree on which kernel a shape takes
    if t <= 1 || a.rows < SMALL_M {
        return matmul(a, b);
    }
    let n = b.cols;
    let mut out = Mat::zeros(a.rows, n);
    if n == 0 || a.cols == 0 {
        return out;
    }
    let packed = pack_b(b);
    // MR-aligned bands so tiles never straddle a task boundary
    let band = a.rows.div_ceil(t).div_ceil(MR) * MR;
    let mut tasks: Vec<(usize, &mut [f32])> = out
        .data
        .chunks_mut(band * n)
        .enumerate()
        .map(|(i, c)| (i * band, c))
        .collect();
    let packed_ref = &packed;
    pool::global().for_each_mut(&mut tasks, |_, task| {
        let (r0, chunk) = (task.0, &mut *task.1);
        kernel_rows(a, packed_ref, r0, chunk.len() / n, chunk, false);
    });
    out
}

// ------------------------------------------------------------------
// Quantized-weight GEMM: decode packed NVFP4 panels in-register
// ------------------------------------------------------------------

/// SIMD level of the quantized-decode microkernel. The two levels are
/// **bitwise identical** by construction: both build each output
/// element's chain as mul-then-add over k ascending on identical decoded
/// operand values (`tests/matmul_kernel.rs` pins this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar decode + accumulate (the property-tested reference).
    Scalar,
    /// AVX2 nibble-unpack + e2m1-LUT decode (`std::arch` intrinsics).
    Avx2,
}

static SIMD_LEVEL: std::sync::OnceLock<SimdLevel> = std::sync::OnceLock::new();

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Resolve the kernel dispatch level once per process: `CHON_SIMD=scalar`
/// or `CHON_SIMD=avx2` forces it (debugging / CI exercising both kernels
/// on one runner); otherwise runtime CPU feature detection decides.
/// Forcing `avx2` on a CPU without it logs a warning and falls back —
/// the choice never changes results, only speed.
pub fn simd_level() -> SimdLevel {
    *SIMD_LEVEL.get_or_init(|| {
        let auto = if avx2_available() { SimdLevel::Avx2 } else { SimdLevel::Scalar };
        match std::env::var("CHON_SIMD") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => SimdLevel::Scalar,
            Ok(v) if v.eq_ignore_ascii_case("avx2") => {
                if avx2_available() {
                    SimdLevel::Avx2
                } else {
                    crate::warn!("CHON_SIMD=avx2 but this CPU lacks AVX2; using scalar");
                    SimdLevel::Scalar
                }
            }
            Ok(v) => {
                crate::warn!("unknown CHON_SIMD={v:?} (expected scalar|avx2); auto-detecting");
                auto
            }
            Err(_) => auto,
        }
    })
}

/// The resolved dispatch level as a log/metric-friendly name.
pub fn simd_level_name() -> &'static str {
    match simd_level() {
        SimdLevel::Scalar => "scalar",
        SimdLevel::Avx2 => "avx2",
    }
}

/// Decode one panel-block of packed e2m1 codes into a row-major
/// `kc × NR` f32 tile: `tile[kk*NR + j] = e2m1(code) * sv[(kk/16)*NR + j]`.
/// `codes` holds `kc` rows of NR/2 bytes (column j in nibble j%2 of byte
/// j/2, low nibble first); `sv` holds the per-(16-row group, column)
/// decoded scale `e4m3::decode(sc) * s_dec`.
fn decode_rows_scalar(codes: &[u8], sv: &[f32], kc: usize, tile: &mut [f32]) {
    use crate::quant::e2m1;
    for kk in 0..kc {
        let row = &codes[kk * (NR / 2)..(kk + 1) * (NR / 2)];
        let svg = &sv[(kk / 16) * NR..(kk / 16) * NR + NR];
        let trow = &mut tile[kk * NR..kk * NR + NR];
        for (j2, &b) in row.iter().enumerate() {
            trow[2 * j2] = e2m1::decode(b & 0xF) * svg[2 * j2];
            trow[2 * j2 + 1] = e2m1::decode(b >> 4) * svg[2 * j2 + 1];
        }
    }
}

/// AVX2 variant of [`decode_rows_scalar`], bitwise identical to it: the
/// e2m1 magnitude comes from the same 8-entry table (one
/// `vpermps` per 8 codes), the sign is applied by XOR-ing the f32 sign
/// bit (bitwise the `-v` negation `e2m1::decode` performs), and the
/// scale multiply is the same single IEEE `mul` per element.
///
/// # Safety
/// Caller must ensure AVX2 is available, `codes.len() >= kc * NR/2`,
/// `tile.len() >= kc * NR` and `sv.len() >= kc.div_ceil(16) * NR`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_rows_avx2(codes: &[u8], sv: &[f32], kc: usize, tile: &mut [f32]) {
    use std::arch::x86_64::*;
    debug_assert!(codes.len() >= kc * (NR / 2));
    debug_assert!(tile.len() >= kc * NR);
    let lut = _mm256_loadu_ps(crate::quant::e2m1::E2M1_VALUES.as_ptr());
    let nib = _mm_set1_epi8(0x0F);
    for kk in 0..kc {
        // 8 bytes = one kk row of 16 nibbles, low nibble first
        let b = _mm_loadl_epi64(codes.as_ptr().add(kk * (NR / 2)) as *const __m128i);
        let lo = _mm_and_si128(b, nib);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), nib);
        // interleave → 16 codes in element order
        let c16 = _mm_unpacklo_epi8(lo, hi);
        let svg = sv.as_ptr().add((kk / 16) * NR);
        let dst = tile.as_mut_ptr().add(kk * NR);
        for half in 0..2 {
            let c = if half == 0 {
                _mm256_cvtepu8_epi32(c16)
            } else {
                _mm256_cvtepu8_epi32(_mm_srli_si128::<8>(c16))
            };
            let mag = _mm256_permutevar8x32_ps(lut, _mm256_and_si256(c, _mm256_set1_epi32(7)));
            let sign =
                _mm256_slli_epi32::<28>(_mm256_and_si256(c, _mm256_set1_epi32(8)));
            let val = _mm256_xor_ps(mag, _mm256_castsi256_ps(sign));
            let v = _mm256_mul_ps(val, _mm256_loadu_ps(svg.add(half * 8)));
            _mm256_storeu_ps(dst.add(half * 8), v);
        }
    }
}

/// Accumulate one activation row against a decoded tile:
/// `acc[j] += a[k0+kk] * tile[kk*NR+j]` for kk ascending — the exact
/// chain the f32 panel kernels build.
fn accum_row_scalar(arow: &[f32], k0: usize, kc: usize, tile: &[f32], acc: &mut [f32; NR]) {
    for kk in 0..kc {
        let av = arow[k0 + kk];
        let tv = &tile[kk * NR..kk * NR + NR];
        for j in 0..NR {
            acc[j] += av * tv[j];
        }
    }
}

/// AVX2 variant of [`accum_row_scalar`]. Deliberately `mul` + `add`, NOT
/// fused-multiply-add: FMA contracts the rounding step and would break
/// bitwise identity with the scalar chain.
///
/// # Safety
/// Caller must ensure AVX2 is available, `arow.len() >= k0 + kc` and
/// `tile.len() >= kc * NR`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accum_row_avx2(arow: &[f32], k0: usize, kc: usize, tile: &[f32], acc: &mut [f32; NR]) {
    use std::arch::x86_64::*;
    let mut a0 = _mm256_loadu_ps(acc.as_ptr());
    let mut a1 = _mm256_loadu_ps(acc.as_ptr().add(8));
    for kk in 0..kc {
        let av = _mm256_set1_ps(*arow.get_unchecked(k0 + kk));
        let t = tile.as_ptr().add(kk * NR);
        a0 = _mm256_add_ps(a0, _mm256_mul_ps(av, _mm256_loadu_ps(t)));
        a1 = _mm256_add_ps(a1, _mm256_mul_ps(av, _mm256_loadu_ps(t.add(8))));
    }
    _mm256_storeu_ps(acc.as_mut_ptr(), a0);
    _mm256_storeu_ps(acc.as_mut_ptr().add(8), a1);
}

/// Compute rows `r0..r0+nrows` of `a * dequant(q)` into `chunk`, decoding
/// each KC×NR panel tile once into a scratch buffer and streaming every
/// activation row through it. Per output element the chain is
/// `0 + Σ_k a[i,k]·w̃[k,j]` with k strictly ascending (blocks ascending,
/// kk ascending; the f32 store/load of the running value between blocks
/// is exact), so the result is bitwise `matmul(a, q.dequantize_mat())`
/// at either SIMD level and under any row banding.
fn quant_kernel_rows(
    a: &Mat,
    q: &crate::quant::nvfp4::PackedQuantMat,
    r0: usize,
    nrows: usize,
    chunk: &mut [f32],
    level: SimdLevel,
) {
    use crate::quant::e4m3;
    let n = q.n;
    debug_assert_eq!(chunk.len(), nrows * n);
    let mut tile = vec![0.0f32; KC * NR];
    let mut sv = vec![0.0f32; KC.div_ceil(16) * NR];
    for blk in &q.blocks {
        let ngroups = blk.kc.div_ceil(16);
        for p in 0..q.npanels {
            let sbase = blk.scales_off + p * ngroups * NR;
            for (s, &code) in
                sv[..ngroups * NR].iter_mut().zip(&q.scales[sbase..sbase + ngroups * NR])
            {
                // decoded per-(group, column) scale, computed in scalar
                // code for both SIMD levels (same bits by construction)
                *s = e4m3::decode(code) * q.s_dec;
            }
            let cbase = blk.codes_off + p * blk.kc * (NR / 2);
            let codes = &q.codes[cbase..cbase + blk.kc * (NR / 2)];
            match level {
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => unsafe {
                    decode_rows_avx2(codes, &sv, blk.kc, &mut tile)
                },
                _ => decode_rows_scalar(codes, &sv, blk.kc, &mut tile),
            }
            let c0 = p * NR;
            let ncols = (n - c0).min(NR);
            for i in 0..nrows {
                let arow = a.row(r0 + i);
                let orow = &mut chunk[i * n + c0..i * n + c0 + ncols];
                let mut acc = [0.0f32; NR];
                acc[..ncols].copy_from_slice(orow);
                match level {
                    #[cfg(target_arch = "x86_64")]
                    SimdLevel::Avx2 => unsafe {
                        accum_row_avx2(arow, blk.k0, blk.kc, &tile, &mut acc)
                    },
                    _ => accum_row_scalar(arow, blk.k0, blk.kc, &tile, &mut acc),
                }
                orow.copy_from_slice(&acc[..ncols]);
            }
        }
    }
}

/// out = a (m × k) * packed-NVFP4 weight (k × n), decoding codes
/// in-register per panel instead of reading an f32 B. Dispatches to the
/// process-wide [`simd_level`].
pub fn matmul_quant_packed(a: &Mat, q: &crate::quant::nvfp4::PackedQuantMat) -> Mat {
    matmul_quant_packed_with(a, q, 1, simd_level())
}

/// Multi-threaded [`matmul_quant_packed`]: row bands on the persistent
/// worker pool. Bit-identical at every thread count — a band boundary
/// never changes any single row's chain.
pub fn matmul_quant_packed_par(
    a: &Mat,
    q: &crate::quant::nvfp4::PackedQuantMat,
    threads: usize,
) -> Mat {
    matmul_quant_packed_with(a, q, threads, simd_level())
}

/// Explicit-level entry point so tests and CI can force both kernels in
/// one process (the env-var dispatch latches once). An `Avx2` request on
/// a CPU without AVX2 silently runs scalar — same bits either way.
pub fn matmul_quant_packed_with(
    a: &Mat,
    q: &crate::quant::nvfp4::PackedQuantMat,
    threads: usize,
    level: SimdLevel,
) -> Mat {
    assert_eq!(a.cols, q.k);
    let level = if level == SimdLevel::Avx2 && !avx2_available() {
        SimdLevel::Scalar
    } else {
        level
    };
    let n = q.n;
    let mut out = Mat::zeros(a.rows, n);
    if a.rows == 0 || n == 0 || a.cols == 0 {
        return out;
    }
    let t = threads.max(1).min(a.rows);
    if t <= 1 {
        quant_kernel_rows(a, q, 0, a.rows, &mut out.data, level);
        return out;
    }
    let band = a.rows.div_ceil(t);
    let mut tasks: Vec<(usize, &mut [f32])> = out
        .data
        .chunks_mut(band * n)
        .enumerate()
        .map(|(i, c)| (i * band, c))
        .collect();
    pool::global().for_each_mut(&mut tasks, |_, task| {
        let (r0, chunk) = (task.0, &mut *task.1);
        quant_kernel_rows(a, q, r0, chunk.len() / n, chunk, level);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(5, 7, 1);
        let eye = Mat::from_fn(7, 7, |r, c| if r == c { 1.0 } else { 0.0 });
        let out = matmul(&a, &eye);
        assert_eq!(out.data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    /// Naive triple loop, f64 accumulation — the reference the packed
    /// kernel is checked against (tolerance, since the chain order and
    /// precision differ).
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for kk in 0..a.cols {
                    acc += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                *out.at_mut(i, j) = acc as f32;
            }
        }
        out
    }

    #[test]
    fn matmul_par_is_bit_identical_to_serial() {
        // the packed kernel's per-row chains are banding-independent, so
        // every thread count must agree bitwise (not just within an eps)
        let a = rand_mat(33, 47, 2);
        let b = rand_mat(47, 29, 3);
        let s = matmul(&a, &b);
        for t in [1, 2, 3, 4, 7, 16] {
            let p = matmul_par(&a, &b, t);
            assert_eq!(s.data, p.data, "threads={t}");
        }
    }

    #[test]
    fn packed_kernel_matches_naive_on_ragged_shapes() {
        // shapes straddling every MR/NR/KC edge, incl. the small-m path
        for (i, &(m, k, n)) in [
            (8, 16, 16),
            (9, 17, 17),
            (8, 300, 33),
            (13, 257, 31),
            (64, 64, 1),
            (1, 64, 64),
            (33, 1, 33),
            (12, 512, 48),
        ]
        .iter()
        .enumerate()
        {
            let a = rand_mat(m, k, 100 + i as u64);
            let b = rand_mat(k, n, 200 + i as u64);
            let got = matmul(&a, &b);
            let want = matmul_naive(&a, &b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!(
                    (x - y).abs() <= 1e-3 * (1.0 + y.abs()),
                    "{m}x{k}x{n}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_handled() {
        let a = Mat::zeros(0, 5);
        let b = rand_mat(5, 4, 1);
        assert_eq!(matmul(&a, &b).data.len(), 0);
        let a = rand_mat(9, 0, 1);
        let b = Mat::zeros(0, 4);
        assert!(matmul(&a, &b).data.iter().all(|&v| v == 0.0));
        let a = rand_mat(9, 5, 1);
        let b = Mat::zeros(5, 0);
        assert_eq!(matmul(&a, &b).data.len(), 0);
        assert_eq!(matmul_par(&a, &b, 4).data.len(), 0);
    }

    #[test]
    fn packed_mat_is_bit_identical_to_matmul() {
        // shapes straddling the SMALL_M dispatch edge plus NR/KC edges:
        // the packed-B cache must be invisible in the bits either way
        for (i, &(m, k, n)) in [
            (1, 16, 16),
            (1, 300, 33),
            (3, 257, 31),
            (7, 512, 48),
            (8, 300, 33),
            (9, 64, 17),
            (33, 129, 65),
            (4, 1, 5),
            (5, 16, 1),
        ]
        .iter()
        .enumerate()
        {
            let a = rand_mat(m, k, 300 + i as u64);
            let b = rand_mat(k, n, 400 + i as u64);
            let pb = PackedMat::pack(&b);
            assert_eq!((pb.rows(), pb.cols()), (k, n));
            let got = matmul_packed(&a, &pb);
            let want = matmul(&a, &b);
            assert_eq!(got.data, want.data, "{m}x{k}x{n}");
            // and a second consumer of the same panels agrees too
            let a2 = rand_mat(m, k, 500 + i as u64);
            assert_eq!(matmul_packed(&a2, &pb).data, matmul(&a2, &b).data);
        }
    }

    #[test]
    fn packed_mat_degenerate_shapes() {
        let b = rand_mat(5, 4, 1);
        let pb = PackedMat::pack(&b);
        assert_eq!(matmul_packed(&Mat::zeros(0, 5), &pb).data.len(), 0);
        let empty_k = PackedMat::pack(&Mat::zeros(0, 4));
        let out = matmul_packed(&rand_mat(3, 0, 2), &empty_k);
        assert!(out.data.iter().all(|&v| v == 0.0));
        let empty_n = PackedMat::pack(&Mat::zeros(5, 0));
        assert_eq!(matmul_packed(&rand_mat(3, 5, 2), &empty_n).data.len(), 0);
    }

    #[test]
    fn matmul_into_accumulates() {
        // both the small-m path and the packed path honor `accumulate`
        for (m, k, n) in [(4, 4, 4), (16, 40, 24)] {
            let a = rand_mat(m, k, 4);
            let b = rand_mat(k, n, 5);
            let mut out = matmul(&a, &b);
            matmul_into(&a, &b, &mut out, true);
            let double = matmul(&a, &b);
            for (x, y) in out.data.iter().zip(&double.data) {
                assert!((x - 2.0 * y).abs() < 1e-3, "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn quant_kernel_is_bit_identical_to_dequantized_matmul() {
        // the quantized kernel's per-element chain is exactly
        // `matmul(a, dequantize_mat())` — bitwise, on every ragged edge
        for (i, &(m, k, n)) in [
            (1, 16, 16),
            (1, 300, 33),
            (3, 257, 31),
            (7, 512, 48),
            (8, 300, 33),
            (9, 64, 17),
            (13, 1, 5),
            (5, 15, 1),
        ]
        .iter()
        .enumerate()
        {
            let a = rand_mat(m, k, 600 + i as u64);
            let w = rand_mat(k, n, 700 + i as u64);
            let q = crate::quant::nvfp4::PackedQuantMat::pack(&w);
            let want = matmul(&a, &q.dequantize_mat());
            let got = matmul_quant_packed_with(&a, &q, 1, SimdLevel::Scalar);
            assert_eq!(got.data, want.data, "{m}x{k}x{n} scalar");
            // Avx2 downgrades to scalar off-x86, so this always holds
            let got = matmul_quant_packed_with(&a, &q, 1, SimdLevel::Avx2);
            assert_eq!(got.data, want.data, "{m}x{k}x{n} avx2");
        }
    }

    #[test]
    fn quant_kernel_is_bit_identical_at_every_thread_count() {
        let a = rand_mat(13, 300, 800);
        let q = crate::quant::nvfp4::PackedQuantMat::pack(&rand_mat(300, 33, 801));
        for level in [SimdLevel::Scalar, SimdLevel::Avx2] {
            let serial = matmul_quant_packed_with(&a, &q, 1, level);
            for t in 2..=8 {
                let p = matmul_quant_packed_with(&a, &q, t, level);
                assert_eq!(serial.data, p.data, "threads={t} {level:?}");
            }
        }
    }

    #[test]
    fn quant_kernel_degenerate_shapes() {
        let q = crate::quant::nvfp4::PackedQuantMat::pack(&rand_mat(5, 4, 1));
        assert_eq!(matmul_quant_packed(&Mat::zeros(0, 5), &q).data.len(), 0);
        let empty_k = crate::quant::nvfp4::PackedQuantMat::pack(&Mat::zeros(0, 4));
        let out = matmul_quant_packed(&rand_mat(3, 0, 2), &empty_k);
        assert!(out.data.iter().all(|&v| v == 0.0));
        let empty_n = crate::quant::nvfp4::PackedQuantMat::pack(&Mat::zeros(5, 0));
        assert_eq!(matmul_quant_packed_par(&rand_mat(3, 5, 2), &empty_n, 4).data.len(), 0);
    }

    #[test]
    fn gather_and_concat() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_cols(&[2, 0]);
        assert_eq!(g.data, vec![3., 1., 6., 4.]);
        let r = a.gather_rows(&[1]);
        assert_eq!(r.data, vec![4., 5., 6.]);
        let h = a.hcat(&g);
        assert_eq!(h.cols, 5);
        assert_eq!(h.row(0), &[1., 2., 3., 3., 1.]);
        let v = a.vcat(&a);
        assert_eq!(v.rows, 4);
    }

    #[test]
    fn transpose_roundtrip() {
        // sizes straddling the TB=32 tile edge
        for (r, c) in [(6, 9), (32, 32), (33, 65), (100, 31)] {
            let a = rand_mat(r, c, (r * c) as u64);
            let t = a.transpose();
            assert_eq!((t.rows, t.cols), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.at(j, i), a.at(i, j));
                }
            }
            assert_eq!(t.transpose().data, a.data);
        }
    }
}
