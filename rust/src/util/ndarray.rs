//! Minimal dense 2-D f32 tensor + cache-blocked matmul.
//!
//! The offline vendor set has no ndarray/nalgebra/rayon; this is the small
//! substrate the HCP pipeline, diagnostics and benches run on. Parallelism
//! uses std::thread::scope over row bands.

use std::fmt;

/// Row-major (rows x cols) f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Gather the given columns into a new (rows x idx.len()) matrix.
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// Gather the given rows into a new (idx.len() x cols) matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (j, &r) in idx.iter().enumerate() {
            out.row_mut(j).copy_from_slice(self.row(r));
        }
        out
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Vertical concatenation [self ; other].
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn mse(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }
}

/// Cache-blocked single-threaded matmul: out = a (m x k) * b (k x n).
/// The k-inner / n-innermost loop autovectorizes under -O.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut out = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut out, false);
    out
}

/// out (+)= a * b; `accumulate` keeps existing contents.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    if !accumulate {
        out.data.fill(0.0);
    }
    const KC: usize = 256;
    let n = b.cols;
    for kb in (0..a.cols).step_by(KC) {
        let kend = (kb + KC).min(a.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Multi-threaded matmul over row bands (std::thread::scope).
pub fn matmul_par(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows);
    let t = threads.max(1).min(a.rows.max(1));
    if t <= 1 || a.rows < 16 {
        return matmul(a, b);
    }
    let n = b.cols;
    let mut out = Mat::zeros(a.rows, n);
    let band = a.rows.div_ceil(t);
    let chunks: Vec<&mut [f32]> = out.data.chunks_mut(band * n).collect();
    std::thread::scope(|s| {
        for (ti, chunk) in chunks.into_iter().enumerate() {
            let r0 = ti * band;
            let rows = chunk.len() / n;
            let a_ref = &a;
            let b_ref = &b;
            s.spawn(move || {
                for i in 0..rows {
                    let arow = a_ref.row(r0 + i);
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b_ref.data[kk * n..(kk + 1) * n];
                        for j in 0..n {
                            orow[j] += av * brow[j];
                        }
                    }
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(5, 7, 1);
        let eye = Mat::from_fn(7, 7, |r, c| if r == c { 1.0 } else { 0.0 });
        let out = matmul(&a, &eye);
        assert_eq!(out.data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_par_matches_serial() {
        let a = rand_mat(33, 47, 2);
        let b = rand_mat(47, 29, 3);
        let s = matmul(&a, &b);
        let p = matmul_par(&a, &b, 4);
        for (x, y) in s.data.iter().zip(&p.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_into_accumulates() {
        let a = rand_mat(4, 4, 4);
        let b = rand_mat(4, 4, 5);
        let mut out = matmul(&a, &b);
        matmul_into(&a, &b, &mut out, true);
        let double = matmul(&a, &b);
        for (x, y) in out.data.iter().zip(&double.data) {
            assert!((x - 2.0 * y).abs() < 1e-4);
        }
    }

    #[test]
    fn gather_and_concat() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_cols(&[2, 0]);
        assert_eq!(g.data, vec![3., 1., 6., 4.]);
        let r = a.gather_rows(&[1]);
        assert_eq!(r.data, vec![4., 5., 6.]);
        let h = a.hcat(&g);
        assert_eq!(h.cols, 5);
        assert_eq!(h.row(0), &[1., 2., 3., 3., 1.]);
        let v = a.vcat(&a);
        assert_eq!(v.rows, 4);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = rand_mat(6, 9, 6);
        assert_eq!(a.transpose().transpose().data, a.data);
    }
}
