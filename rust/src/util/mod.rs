//! Shared substrate: PRNG, tiny ndarray, mini property-test harness,
//! logging. These replace crates absent from the offline vendor set
//! (DESIGN.md §Substitutions).

pub mod json;
pub mod logger;
pub mod ndarray;
pub mod pool;
pub mod prng;
pub mod proptest;
