//! `chon` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train           train one (model, recipe) run with monitoring
//!   ablate-table2   the Tab. 2 recipe ablation grid
//!   ablate-table3   the Tab. 3 operator sensitivity study
//!   eval-suite      the Tab. 1 downstream eval substitute
//!   diag            longitudinal diagnostics run (high probe frequency)
//!   serve           checkpoint-backed inference server (request batching)
//!   client          protocol client / load generator
//!   loadtest        scenario + chaos load harness with SLO gates
//!   tail            follow / summarize a run's trace.jsonl
//!   bench-diff      gate bench JSON against the checked-in baseline
//!   info            list available models/recipes (or pjrt artifacts)
//!
//! Flags are `--key value`; see `chon help`.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use chon::config::RunConfig;
use chon::coordinator::{ablation, evalsuite, Trainer};
use chon::runtime::native;
use chon::serve::{
    client, ClientOpts, ModelRegistry, RegistryOpts, ServeOpts, Server, StoreOpts,
};

const HELP: &str = "\
chon — CHON/NVFP4 training coordinator

USAGE: chon <command> [--key value ...]

COMMANDS:
  train          train one (model, recipe); writes runs/<model>_<recipe>/
  ablate-table2  run the Tab. 2 recipe grid (GLA ablation)
  ablate-table3  run the Tab. 3 operator sensitivity study
  eval-suite     train bf16/fp8/nvfp4/chon and report downstream scores
  finetune       post-training gap study (Fig. 15c substitute)
  diag           longitudinal diagnostics (diag every 10 steps)
  serve          serve a checkpoint over TCP + HTTP with request batching
  client         talk to a server; --requests N turns it into a load gen
  loadtest       run the scenario/chaos load harness against spawned
                 servers; writes OUT_DIR/loadtest/summary.json
  tail           read a run dir's trace.jsonl: live follow (--follow),
                 offline summary, or Chrome trace export
  bench-diff     diff a bench JSON report against the checked-in baseline
  info           list models/recipes (native) or artifacts (pjrt)
  help           this text

COMMON FLAGS:
  --backend B       native|pjrt (default native; pjrt needs --features pjrt)
  --artifacts DIR   (default artifacts)   --model NAME   (default tiny_gla)
  --recipe NAME     (default chon)        --steps N      (default: artifact)
  --seed N          --out-dir DIR         --diag-every N --eval-every N
  --log-every N     --checkpoint-dir DIR  --config FILE.toml
  --threads N       worker-pool lanes (default: all cores; CHON_THREADS wins)
  --shards N        data-parallel shards, native train only (default 1;
                    bit-identical trajectories for every N)
  --resume DIR      resume params+Adam+step from a checkpoint dir (errors
                    on model/recipe mismatch)

TRAIN TELEMETRY FLAGS:
  --metrics-port P  train: serve live GET /metrics (Prometheus) and
                    GET /progress (JSON) from the training process on
                    port P (0 = off, the default)
  --no-trace        train/diag: skip the crash-durable JSONL run trace
                    (runs/<model>_<recipe>/trace.jsonl, on by default)

TAIL USAGE: chon tail RUNDIR [--follow] [--chrome-trace FILE]
  RUNDIR            a run dir holding trace.jsonl, the file itself, or
                    an out-dir root containing exactly one run dir
  --follow          poll for new events and print them live (stops at
                    run_end)
  --chrome-trace F  write phase spans as Chrome trace-event JSON (open
                    in chrome://tracing or ui.perfetto.dev)

SERVE/CLIENT FLAGS:
  --checkpoint DIR  checkpoint dir (or parent; highest step wins);
                    registered as model "default"
  --model NAME=DIR  register a named model (repeatable; first registered
                    is the default route). serve only — a plain --model
                    NAME[,NAME] is the client-side routing list
  --max-resident-models N  models with a loaded engine at once (0=unlim.;
                    LRU models unload, sessions park, reload on demand)
  --reload-poll-ms MS  min interval between checkpoint generation probes
                    (default 500; a republished checkpoint hot-reloads)
  --host H          (default 127.0.0.1)   --port P       (default 7411; 0=any)
  --http-port P     HTTP front end (default 7412; 0=any; off=disabled)
  --idle-timeout-ms MS  drop connections idle this long (default 60000;
                    0=never; the epoll reactor holds 10k+ idle conns free)
  --max-conns N     cap on open connections (default 0 = unlimited)
  --max-batch N     (default 8)           --max-wait-us U (default 2000)
  --max-resident-sessions N  idle named sessions kept in RAM (0=unlimited)
  --max-kv-tokens N          resident idle KV positions cap (0=unlimited)
  --spill-dir DIR            where evicted sessions go (default: temp dir)
  --requests N      client load mode (sprays across --model names,
                    per-model latency percentiles)
  --concurrency C   (default 4)
  --idle-conns N    park N idle connections during the load run and verify
                    they all survive (connection-scaling smoke)
  --max-tokens N    (default 32)          --temp T       (default 0 = greedy)
  --prompt TEXT     --session ID          (continue a named session, SGEN)
  --shutdown        (ask the server to drain + stop)
  --obs-outliers    serve: sample per-request HCP hot-channel hits and
                    residual energy into GET /metrics (small decode cost)
  --packed-compute  serve: keep NVFP4 weights as packed 4-bit codes decoded
                    in-register by the GEMM (hot channels split into an f32
                    side-GEMM). A distinct recipe mode vs fake-quant; see
                    the README accuracy contract. CHON_SIMD=scalar|avx2
                    forces the kernel dispatch
  --metrics-port P  client load mode: scrape /metrics on P before and after
                    the run and assert key series exist and increase

BENCH-DIFF FLAGS:
  --baseline FILE   (default benches/baseline/perf_baseline.json)
  --current FILE    (default runs/bench/perf.json)
  --tolerance PCT   (default 25; fail on >PCT% median regression)

LOADTEST FLAGS:
  --scenario NAME   run one scenario (repeatable; default: all of
                    fanout churn poisson ragged spray evict_storm
                    reload kill_resume)
  --quick           smaller workloads, same coverage (CI smoke mode)
  --checkpoint DIR  serve this checkpoint (default: train a fresh tiny
                    one under OUT_DIR/loadtest/ckpt)
  --seed N          schedules are a pure function of the seed: same
                    seed, same request schedule (pinned by the
                    schedule_digest field in summary.json)
  --check FILE      gate mode: diff a summary against baseline FILE,
                    bench-diff style (exit 1 on SLO violations)
  --current FILE    summary to gate (default OUT_DIR/loadtest/summary.json)
  --tolerance PCT   gate: latency/RSS tolerance (default 50)
  --abs-ms MS       gate: absolute latency floor — a percentile must be
                    over tolerance AND over this to fail (default 20)
  --inject-latency-ms MS  add artificial client-side latency per request
                    (CI uses this to prove the gate catches regressions)
  --repeats N       run every scenario N times (default 1); stage
                    latency histograms are merged across repeats and
                    reported as stages_merged in summary.json

The native backend runs the tiny GLA/SA training step in pure Rust — no
artifacts directory and no libxla needed; runs are bit-reproducible for a
fixed --seed. Wire protocol: `GEN <max_tokens> <temp>\\t<prompt>` (or
`SGEN <session> ...` to continue a named session, either behind a
`MODEL <name>` routing prefix) in, streamed `TOK <piece>` lines +
`DONE <n> <ms>` out; HTTP: POST /generate (optional \"model\" key),
GET /stats, GET /metrics (Prometheus text), POST /shutdown (see
rust/README.md).
";

fn is_native(cfg: &RunConfig) -> bool {
    cfg.backend == "native"
}

fn default_recipes(cfg: &RunConfig) -> Vec<String> {
    if is_native(cfg) {
        return native::available_recipes();
    }
    // every train_<model>_<recipe> artifact that exists, bf16 first
    let mut found = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&cfg.artifacts) {
        let prefix = format!("train_{}_", cfg.model);
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(rest) = name
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".manifest.txt"))
            {
                if !rest.starts_with("only_") {
                    found.push(rest.to_string());
                }
            }
        }
    }
    found.sort_by_key(|r| (r != "bf16", r.clone()));
    found
}

fn sensitivity_ops(cfg: &RunConfig) -> Result<Vec<String>> {
    if is_native(cfg) {
        return native::sensitivity_ops_for(&cfg.model);
    }
    let mut ops = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&cfg.artifacts) {
        let prefix = format!("train_{}_only_", cfg.model);
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(rest) = name
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".manifest.txt"))
            {
                ops.push(rest.replacen('_', ".", 1));
            }
        }
    }
    ops.sort();
    Ok(ops)
}

/// `chon tail RUNDIR [--follow] [--chrome-trace FILE]` — positional
/// target, so it parses its own flags like `bench-diff` does.
fn tail_cmd(args: &[String]) -> Result<()> {
    let mut target: Option<PathBuf> = None;
    let mut follow = false;
    let mut chrome: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--follow" => follow = true,
            "--chrome-trace" => {
                chrome = Some(PathBuf::from(it.next().ok_or_else(|| {
                    anyhow::anyhow!("--chrome-trace needs a file path")
                })?));
            }
            other if other.starts_with("--") => {
                bail!("unknown tail flag {other:?}");
            }
            path => {
                if target.is_some() {
                    bail!("tail takes one RUNDIR, got a second: {path:?}");
                }
                target = Some(PathBuf::from(path));
            }
        }
    }
    let target =
        target.ok_or_else(|| anyhow::anyhow!("usage: chon tail RUNDIR [--follow] [--chrome-trace FILE]"))?;
    chon::obs::tail::run(&chon::obs::tail::TailOpts { target, follow, chrome })
}

/// `bench-diff` takes its own flags (file paths, not run config).
fn bench_diff(args: &[String]) -> Result<()> {
    let mut baseline = PathBuf::from("benches/baseline/perf_baseline.json");
    let mut current = PathBuf::from("runs/bench/perf.json");
    let mut tolerance = 25.0f64;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        let mut next = || {
            it.next()
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("{arg} needs a value"))
        };
        match arg.as_str() {
            "--baseline" => baseline = PathBuf::from(next()?),
            "--current" => current = PathBuf::from(next()?),
            "--tolerance" => tolerance = next()?.parse()?,
            other => bail!("unknown bench-diff flag {other:?}"),
        }
    }
    let base = chon::bench::read_report(&baseline)?;
    let cur = chon::bench::read_report(&current)?;
    println!(
        "bench-diff: {} vs {} (tolerance {tolerance}%)",
        current.display(),
        baseline.display()
    );
    let regressed = chon::bench::diff_reports(&base, &cur, tolerance);
    if !regressed.is_empty() {
        bail!(
            "{} hot path(s) regressed >{}%: {}",
            regressed.len(),
            tolerance,
            regressed.join(", ")
        );
    }
    println!("no regressions beyond {tolerance}%");
    Ok(())
}

fn main() -> Result<()> {
    chon::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        print!("{HELP}");
        return Ok(());
    };
    if cmd == "bench-diff" {
        return bench_diff(&args[1..]);
    }
    if cmd == "tail" {
        // positional RUNDIR would trip cfg.apply_args, so tail parses
        // its own flags like bench-diff
        return tail_cmd(&args[1..]);
    }
    let mut cfg = RunConfig::default();
    cfg.apply_args(&args[1..])?;
    // --model is subcommand-overloaded (serve: NAME=DIR registry entry;
    // train: model-config name; client: routing name list) — reject the
    // wrong spelling early instead of silently ignoring it
    if !cfg.serve_models.is_empty() && cmd != "serve" {
        bail!(
            "--model NAME=DIR registers a serve model; `chon {cmd}` takes \
             a plain --model value"
        );
    }
    if cmd == "serve" && !cfg.client_models.is_empty() {
        bail!(
            "`chon serve` takes --model NAME=DIR (plain --model NAME is \
             the client-side routing flag)"
        );
    }
    // size the persistent worker pool before the first parallel kernel
    chon::util::pool::configure_threads(cfg.threads);

    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{HELP}"),
        "info" => {
            if is_native(&cfg) {
                println!("backend: native (pure Rust, no artifacts needed)");
                println!("models:  {}", native::available_models().join(" "));
                println!("recipes: {}", native::available_recipes().join(" "));
                println!(
                    "sensitivity ops ({}): {}",
                    cfg.model,
                    native::sensitivity_ops_for(&cfg.model)?.join(" ")
                );
            } else {
                let idx = cfg.artifacts.join("index.txt");
                let listing = std::fs::read_to_string(&idx)
                    .with_context(|| format!("no index at {}", idx.display()))?;
                println!("artifacts in {}:", cfg.artifacts.display());
                print!("{listing}");
            }
        }
        "train" => {
            let steps = cfg.steps;
            let mut tr = Trainer::new(cfg)?;
            if let Some(ckpt) = tr.cfg.resume.clone() {
                tr.restore(&ckpt)
                    .with_context(|| format!("resuming from {}", ckpt.display()))?;
                println!(
                    "resumed {}/{} at step {}",
                    tr.cfg.model, tr.cfg.recipe, tr.state.step
                );
            }
            // live telemetry: gauges/histograms fed by the trainer, the
            // crash-durable trace + incremental train.csv, and (with
            // --metrics-port) a /metrics + /progress listener thread
            let obs = chon::obs::train::TrainObs::new(tr.spans.clone());
            obs.set_build_info(&tr.cfg.backend, &tr.cfg.recipe);
            tr.set_obs(obs.clone());
            tr.enable_run_outputs()?;
            let metrics_srv = if tr.cfg.metrics_port > 0 {
                let srv = chon::obs::train::MetricsServer::serve(
                    &tr.cfg.host,
                    tr.cfg.metrics_port,
                    obs,
                )?;
                println!("train metrics on {}:{}", tr.cfg.host, srv.port());
                Some(srv)
            } else {
                None
            };
            let n = if steps > 0 { steps } else { tr.total_steps };
            tr.train(n)?;
            if tr.ensure_eval().is_some() {
                let (l, a) = tr.evaluate(4)?;
                println!("final eval: loss {l:.4} acc {a:.3}");
            }
            let dir = tr.write_outputs()?;
            // leave a final checkpoint unless the in-loop cadence (every
            // 100 steps) already wrote this exact step
            if let Some(ckpt_dir) = tr.cfg.checkpoint_dir.clone() {
                if tr.state.step % 100 != 0 {
                    let p = tr.save_checkpoint_to(&ckpt_dir)?;
                    println!("checkpoint written to {}", p.display());
                }
            }
            println!(
                "trained {} steps; final loss {:.4}; mean step {:.0} ms; outputs in {}",
                n,
                tr.log.final_loss().unwrap_or(f32::NAN),
                tr.log.mean_step_ms(),
                dir.display()
            );
            // scrape-after-finish races in CI are real: keep the
            // listener up through the final outputs, then stop cleanly
            if let Some(mut srv) = metrics_srv {
                srv.stop();
            }
        }
        "serve" => {
            // --checkpoint registers "default"; --model NAME=DIR adds
            // named models (first registered is the default route)
            let mut entries: Vec<(String, std::path::PathBuf)> = Vec::new();
            if let Some(dir) = cfg.checkpoint_dir.clone() {
                entries.push(("default".to_string(), dir));
            }
            entries.extend(cfg.serve_models.iter().cloned());
            if entries.is_empty() {
                bail!(
                    "serve needs --checkpoint DIR and/or --model NAME=DIR \
                     (dirs written by `chon train --checkpoint-dir`)"
                );
            }
            let reg_opts = RegistryOpts {
                max_batch: cfg.max_batch,
                max_wait_us: cfg.max_wait_us,
                seed: cfg.seed,
                store_opts: StoreOpts {
                    max_resident_sessions: cfg.max_resident_sessions,
                    max_kv_tokens: cfg.max_kv_tokens,
                    spill_dir: cfg.spill_dir.clone(),
                },
                max_resident_models: cfg.max_resident_models,
                reload_poll_ms: cfg.reload_poll_ms,
                load_delay_ms: 0,
                obs: chon::obs::global(),
                obs_outliers: cfg.obs_outliers,
                packed_compute: cfg.packed_compute,
            };
            reg_opts.obs.set_build_info(
                "native",
                if cfg.packed_compute { "packed" } else { "fake-quant" },
            );
            if cfg.packed_compute {
                println!(
                    "packed-compute on: SIMD kernel {}",
                    chon::util::ndarray::simd_level_name()
                );
            }
            let mut registry = ModelRegistry::new(reg_opts);
            for (name, dir) in &entries {
                registry.register(name, dir)?;
                println!("registered model {name} -> {}", dir.display());
            }
            let opts = ServeOpts {
                host: cfg.host.clone(),
                port: cfg.port,
                http_port: cfg.http_port,
                idle_timeout_ms: cfg.idle_timeout_ms,
                max_conns: cfg.max_conns,
            };
            let server = Server::bind(registry, &opts)?;
            println!("listening on {}:{}", opts.host, server.port());
            if let Some(hp) = server.http_port() {
                println!("http front end on {}:{}", opts.host, hp);
            }
            let stats = server.run()?;
            println!("final stats: {stats}");
        }
        "client" => {
            let model = cfg.client_models.first().map(|s| s.as_str());
            if cfg.shutdown {
                client::send_shutdown(&cfg.host, cfg.port)?;
                println!("shutdown sent to {}:{}", cfg.host, cfg.port);
            } else if cfg.requests == 0 {
                let (text, n, ms) = match &cfg.session {
                    Some(sid) => client::generate_session_once_for(
                        &cfg.host,
                        cfg.port,
                        model,
                        sid,
                        &cfg.prompt,
                        cfg.max_tokens,
                        cfg.temp,
                    )?,
                    None => client::generate_once_for(
                        &cfg.host,
                        cfg.port,
                        model,
                        &cfg.prompt,
                        cfg.max_tokens,
                        cfg.temp,
                    )?,
                };
                println!("{text}");
                println!("[{n} tokens in {ms:.1} ms]");
            } else {
                if cfg.session.is_some() {
                    bail!(
                        "--session applies to one-shot requests only; load \
                         mode (--requests N) always sends ephemeral GENs"
                    );
                }
                let opts = ClientOpts {
                    host: cfg.host.clone(),
                    port: cfg.port,
                    requests: cfg.requests,
                    concurrency: cfg.concurrency,
                    max_tokens: cfg.max_tokens,
                    temp: cfg.temp,
                    prompt: cfg.prompt.clone(),
                    models: cfg.client_models.clone(),
                    idle_conns: cfg.idle_conns,
                };
                // scrape-and-assert mode: snapshot /metrics before the
                // run so the post-run scrape can prove movement
                let metrics_before = if cfg.metrics_port > 0 {
                    Some(client::fetch_metrics(&cfg.host, cfg.metrics_port)?)
                } else {
                    None
                };
                let report = client::run_load(&opts)?;
                client::print_report(&opts, &report);
                if let Some(before) = &metrics_before {
                    let after =
                        client::fetch_metrics(&cfg.host, cfg.metrics_port)?;
                    client::assert_metrics_progress(before, &after)?;
                    println!(
                        "metrics scrape OK: key series present and increasing"
                    );
                }
                if report.requests_ok() == 0
                    || report.failures > 0
                    || report.empty_responses > 0
                    || report.idle_alive < report.idle_opened
                {
                    bail!(
                        "load run unhealthy: {} ok, {} empty, {} failed \
                         threads, {}/{} idle conns alive",
                        report.requests_ok(),
                        report.empty_responses,
                        report.failures,
                        report.idle_alive,
                        report.idle_opened
                    );
                }
            }
        }
        "diag" => {
            cfg.diag_every = if cfg.diag_every == 0 { 10 } else { cfg.diag_every };
            let steps = cfg.steps;
            let mut tr = Trainer::new(cfg)?;
            // diag runs get the trace too — probe-dense traces are what
            // `chon tail` persistence analysis is for
            tr.enable_run_outputs()?;
            let n = if steps > 0 { steps } else { tr.total_steps };
            tr.train(n)?;
            let dir = tr.write_outputs()?;
            for (comp, series) in tr.monitor.hot_channel_persistence(8) {
                let head: Vec<f64> = series.iter().take(3).map(|&(_, j)| j).collect();
                let tail: Vec<f64> =
                    series.iter().rev().take(3).rev().map(|&(_, j)| j).collect();
                println!(
                    "hot-channel persistence {comp}: early {head:.2?} -> late {tail:.2?}"
                );
            }
            println!("diagnostics written to {}", dir.display());
        }
        "ablate-table2" => {
            let recipes = default_recipes(&cfg);
            if recipes.is_empty() {
                bail!("no train artifacts for model {}", cfg.model);
            }
            let steps = if cfg.steps > 0 { cfg.steps } else { 200 };
            let rows = ablation::table2(&cfg, &recipes, steps, 10)?;
            ablation::print_table2(&rows);
            std::fs::create_dir_all(&cfg.out_dir)?;
            let p = cfg.out_dir.join("table2.csv");
            ablation::write_table2(&rows, &p)?;
            println!("written {}", p.display());
        }
        "ablate-table3" => {
            let ops = sensitivity_ops(&cfg)?;
            if ops.is_empty() {
                bail!(
                    "no sensitivity artifacts for {} (build with --set core/full)",
                    cfg.model
                );
            }
            let steps = if cfg.steps > 0 { cfg.steps } else { 150 };
            let rows = ablation::table3(&cfg, &ops, steps, 10)?;
            ablation::print_table3(&rows);
            std::fs::create_dir_all(&cfg.out_dir)?;
            let p = cfg.out_dir.join("table3.csv");
            ablation::write_table3(&rows, &p)?;
            println!("written {}", p.display());
        }
        "finetune" => {
            let steps = if cfg.steps > 0 { cfg.steps } else { 120 };
            let points = chon::coordinator::finetune::finetune_gap_study(
                &cfg, "nvfp4", steps, steps, (steps / 6).max(1),
            )?;
            chon::coordinator::finetune::print_gap_trajectory("nvfp4", &points);
        }
        "loadtest" => {
            if let Some(baseline) = &cfg.loadtest_check {
                // gate mode: diff an existing summary against a baseline
                let current = cfg.loadtest_current.clone().unwrap_or_else(|| {
                    cfg.out_dir.join("loadtest").join("summary.json")
                });
                return chon::loadtest::check_files(
                    baseline,
                    &current,
                    cfg.slo_tolerance,
                    cfg.slo_abs_ms,
                );
            }
            let opts = chon::loadtest::LoadtestOpts {
                scenarios: cfg.loadtest_scenarios.clone(),
                quick: cfg.quick,
                seed: cfg.seed,
                out_root: cfg.out_dir.join("loadtest"),
                checkpoint: cfg.checkpoint_dir.clone(),
                bin: None, // spawn servers from this very binary
                inject_latency_ms: cfg.inject_latency_ms,
                model: cfg.model.clone(),
                recipe: cfg.recipe.clone(),
                repeats: cfg.repeats.max(1),
            };
            let summary = chon::loadtest::run(&opts)?;
            if !summary.all_ok() {
                let failed: Vec<&str> = summary
                    .scenarios
                    .iter()
                    .filter(|s| !s.ok)
                    .map(|s| s.name.as_str())
                    .collect();
                bail!("loadtest scenario(s) failed: {}", failed.join(", "));
            }
        }
        "eval-suite" => {
            let all = default_recipes(&cfg);
            let wanted = ["bf16", "fp8", "nvfp4", "chon"];
            let recipes: Vec<String> = all
                .into_iter()
                .filter(|r| wanted.contains(&r.as_str()))
                .collect();
            let steps = if cfg.steps > 0 { cfg.steps } else { 200 };
            let rows = evalsuite::run_suite(&cfg, &recipes, steps)?;
            evalsuite::print_suite(&rows);
        }
        other => bail!("unknown command {other:?}; see `chon help`"),
    }
    Ok(())
}
