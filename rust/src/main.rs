//! `chon` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train           train one (model, recipe) run with monitoring
//!   ablate-table2   the Tab. 2 recipe ablation grid
//!   ablate-table3   the Tab. 3 operator sensitivity study
//!   eval-suite      the Tab. 1 downstream eval substitute
//!   diag            longitudinal diagnostics run (high probe frequency)
//!   info            list available models/recipes (or pjrt artifacts)
//!
//! Flags are `--key value`; see `chon help`.

use anyhow::{bail, Context, Result};

use chon::config::RunConfig;
use chon::coordinator::{ablation, evalsuite, Trainer};
use chon::runtime::native;

const HELP: &str = "\
chon — CHON/NVFP4 training coordinator

USAGE: chon <command> [--key value ...]

COMMANDS:
  train          train one (model, recipe); writes runs/<model>_<recipe>/
  ablate-table2  run the Tab. 2 recipe grid (GLA ablation)
  ablate-table3  run the Tab. 3 operator sensitivity study
  eval-suite     train bf16/fp8/nvfp4/chon and report downstream scores
  finetune       post-training gap study (Fig. 15c substitute)
  diag           longitudinal diagnostics (diag every 10 steps)
  info           list models/recipes (native) or artifacts (pjrt)
  help           this text

COMMON FLAGS:
  --backend B       native|pjrt (default native; pjrt needs --features pjrt)
  --artifacts DIR   (default artifacts)   --model NAME   (default tiny_gla)
  --recipe NAME     (default chon)        --steps N      (default: artifact)
  --seed N          --out-dir DIR         --diag-every N --eval-every N
  --log-every N     --checkpoint-dir DIR  --config FILE.toml

The native backend runs the tiny GLA/SA training step in pure Rust — no
artifacts directory and no libxla needed; runs are bit-reproducible for a
fixed --seed.
";

fn is_native(cfg: &RunConfig) -> bool {
    cfg.backend == "native"
}

fn default_recipes(cfg: &RunConfig) -> Vec<String> {
    if is_native(cfg) {
        return native::available_recipes();
    }
    // every train_<model>_<recipe> artifact that exists, bf16 first
    let mut found = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&cfg.artifacts) {
        let prefix = format!("train_{}_", cfg.model);
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(rest) = name
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".manifest.txt"))
            {
                if !rest.starts_with("only_") {
                    found.push(rest.to_string());
                }
            }
        }
    }
    found.sort_by_key(|r| (r != "bf16", r.clone()));
    found
}

fn sensitivity_ops(cfg: &RunConfig) -> Result<Vec<String>> {
    if is_native(cfg) {
        return native::sensitivity_ops_for(&cfg.model);
    }
    let mut ops = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&cfg.artifacts) {
        let prefix = format!("train_{}_only_", cfg.model);
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(rest) = name
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".manifest.txt"))
            {
                ops.push(rest.replacen('_', ".", 1));
            }
        }
    }
    ops.sort();
    Ok(ops)
}

fn main() -> Result<()> {
    chon::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        print!("{HELP}");
        return Ok(());
    };
    let mut cfg = RunConfig::default();
    cfg.apply_args(&args[1..])?;

    match cmd.as_str() {
        "help" | "--help" | "-h" => print!("{HELP}"),
        "info" => {
            if is_native(&cfg) {
                println!("backend: native (pure Rust, no artifacts needed)");
                println!("models:  {}", native::available_models().join(" "));
                println!("recipes: {}", native::available_recipes().join(" "));
                println!(
                    "sensitivity ops ({}): {}",
                    cfg.model,
                    native::sensitivity_ops_for(&cfg.model)?.join(" ")
                );
            } else {
                let idx = cfg.artifacts.join("index.txt");
                let listing = std::fs::read_to_string(&idx)
                    .with_context(|| format!("no index at {}", idx.display()))?;
                println!("artifacts in {}:", cfg.artifacts.display());
                print!("{listing}");
            }
        }
        "train" => {
            let steps = cfg.steps;
            let mut tr = Trainer::new(cfg)?;
            let n = if steps > 0 { steps } else { tr.total_steps };
            tr.train(n)?;
            if tr.ensure_eval().is_some() {
                let (l, a) = tr.evaluate(4)?;
                println!("final eval: loss {l:.4} acc {a:.3}");
            }
            let dir = tr.write_outputs()?;
            println!(
                "trained {} steps; final loss {:.4}; mean step {:.0} ms; outputs in {}",
                n,
                tr.log.final_loss().unwrap_or(f32::NAN),
                tr.log.mean_step_ms(),
                dir.display()
            );
        }
        "diag" => {
            cfg.diag_every = if cfg.diag_every == 0 { 10 } else { cfg.diag_every };
            let steps = cfg.steps;
            let mut tr = Trainer::new(cfg)?;
            let n = if steps > 0 { steps } else { tr.total_steps };
            tr.train(n)?;
            let dir = tr.write_outputs()?;
            for (comp, series) in tr.monitor.hot_channel_persistence(8) {
                let head: Vec<f64> = series.iter().take(3).map(|&(_, j)| j).collect();
                let tail: Vec<f64> =
                    series.iter().rev().take(3).rev().map(|&(_, j)| j).collect();
                println!(
                    "hot-channel persistence {comp}: early {head:.2?} -> late {tail:.2?}"
                );
            }
            println!("diagnostics written to {}", dir.display());
        }
        "ablate-table2" => {
            let recipes = default_recipes(&cfg);
            if recipes.is_empty() {
                bail!("no train artifacts for model {}", cfg.model);
            }
            let steps = if cfg.steps > 0 { cfg.steps } else { 200 };
            let rows = ablation::table2(&cfg, &recipes, steps, 10)?;
            ablation::print_table2(&rows);
            std::fs::create_dir_all(&cfg.out_dir)?;
            let p = cfg.out_dir.join("table2.csv");
            ablation::write_table2(&rows, &p)?;
            println!("written {}", p.display());
        }
        "ablate-table3" => {
            let ops = sensitivity_ops(&cfg)?;
            if ops.is_empty() {
                bail!(
                    "no sensitivity artifacts for {} (build with --set core/full)",
                    cfg.model
                );
            }
            let steps = if cfg.steps > 0 { cfg.steps } else { 150 };
            let rows = ablation::table3(&cfg, &ops, steps, 10)?;
            ablation::print_table3(&rows);
            std::fs::create_dir_all(&cfg.out_dir)?;
            let p = cfg.out_dir.join("table3.csv");
            ablation::write_table3(&rows, &p)?;
            println!("written {}", p.display());
        }
        "finetune" => {
            let steps = if cfg.steps > 0 { cfg.steps } else { 120 };
            let points = chon::coordinator::finetune::finetune_gap_study(
                &cfg, "nvfp4", steps, steps, (steps / 6).max(1),
            )?;
            chon::coordinator::finetune::print_gap_trajectory("nvfp4", &points);
        }
        "eval-suite" => {
            let all = default_recipes(&cfg);
            let wanted = ["bf16", "fp8", "nvfp4", "chon"];
            let recipes: Vec<String> = all
                .into_iter()
                .filter(|r| wanted.contains(&r.as_str()))
                .collect();
            let steps = if cfg.steps > 0 { cfg.steps } else { 200 };
            let rows = evalsuite::run_suite(&cfg, &recipes, steps)?;
            evalsuite::print_suite(&rows);
        }
        other => bail!("unknown command {other:?}; see `chon help`"),
    }
    Ok(())
}
