//! Data substrate: synthetic corpus (RedPajama substitute), tokenizer,
//! packing/batching with background prefetch.

pub mod batcher;
pub mod corpus;
pub mod tokenizer;
