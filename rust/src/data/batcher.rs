//! Sequence packing + batching with background prefetch.
//!
//! The tokenized stream is packed into fixed-length windows (next-token
//! targets = inputs shifted by one). A std-thread prefetcher keeps a small
//! queue of ready batches so literal construction overlaps PJRT execution
//! — the tokio-free version of the coordinator's async data path.

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::corpus::Corpus;
use crate::data::tokenizer::Tokenizer;

/// One training batch: row-major (batch, seq_len) token ids + targets.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Streaming packer over an unbounded corpus.
pub struct Batcher {
    corpus: Corpus,
    tokenizer: Tokenizer,
    batch: usize,
    seq_len: usize,
    vocab: usize,
    buf: VecDeque<u32>,
    stream_seed: u64,
    chunk_bytes: usize,
}

impl Batcher {
    pub fn new(
        corpus: Corpus,
        tokenizer: Tokenizer,
        batch: usize,
        seq_len: usize,
        vocab: usize,
    ) -> Self {
        Batcher {
            corpus,
            tokenizer,
            batch,
            seq_len,
            vocab,
            buf: VecDeque::new(),
            stream_seed: 0,
            chunk_bytes: 16 * 1024,
        }
    }

    fn refill(&mut self) {
        let text = self.corpus.generate(self.chunk_bytes, self.stream_seed);
        self.stream_seed += 1;
        for t in self.tokenizer.encode(&text) {
            // clamp into the model vocab (ids >= vocab map to id % vocab)
            self.buf.push_back(t % self.vocab as u32);
        }
    }

    /// Produce the next packed batch (never fails; corpus is unbounded).
    pub fn next_batch(&mut self) -> Batch {
        let need = self.batch * (self.seq_len + 1);
        while self.buf.len() < need {
            self.refill();
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            let row: Vec<u32> = self.buf.drain(..self.seq_len + 1).collect();
            tokens.extend(row[..self.seq_len].iter().map(|&t| t as i32));
            targets.extend(row[1..].iter().map(|&t| t as i32));
        }
        Batch { batch: self.batch, seq_len: self.seq_len, tokens, targets }
    }
}

/// Background prefetcher: produces batches on a worker thread.
pub struct Prefetcher {
    rx: Receiver<Batch>,
    _handle: JoinHandle<()>,
}

impl Prefetcher {
    pub fn spawn(mut batcher: Batcher, depth: usize) -> Self {
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || loop {
            let b = batcher.next_batch();
            if tx.send(b).is_err() {
                break; // consumer dropped
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Batch {
        self.rx.recv().expect("prefetch thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn mk_batcher(seed: u64) -> Batcher {
        let c = Corpus::new(CorpusConfig { seed, ..CorpusConfig::default() });
        let t = Tokenizer::byte_level();
        Batcher::new(c, t, 4, 32, 256)
    }

    #[test]
    fn shapes_and_vocab_bounds() {
        let mut b = mk_batcher(0);
        for _ in 0..5 {
            let batch = b.next_batch();
            assert_eq!(batch.tokens.len(), 4 * 32);
            assert_eq!(batch.targets.len(), 4 * 32);
            assert!(batch.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut b = mk_batcher(1);
        let batch = b.next_batch();
        for row in 0..4 {
            let t = &batch.tokens[row * 32..(row + 1) * 32];
            let y = &batch.targets[row * 32..(row + 1) * 32];
            assert_eq!(&t[1..], &y[..31], "row {row}");
        }
    }

    #[test]
    fn deterministic_stream() {
        let mut a = mk_batcher(2);
        let mut b = mk_batcher(2);
        for _ in 0..3 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn batches_advance() {
        let mut b = mk_batcher(3);
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_ne!(b1.tokens, b2.tokens, "no repeated windows");
    }

    #[test]
    fn prefetcher_matches_direct() {
        let direct: Vec<Batch> = {
            let mut b = mk_batcher(4);
            (0..4).map(|_| b.next_batch()).collect()
        };
        let pf = Prefetcher::spawn(mk_batcher(4), 2);
        for d in direct {
            assert_eq!(pf.next().tokens, d.tokens);
        }
    }
}
