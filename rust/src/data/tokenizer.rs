//! Byte-level tokenizer with a greedy bigram-merge vocabulary (micro-BPE).
//!
//! Vocab layout: [0..256) raw bytes, [256..vocab) learned merges. A 256-
//! entry vocab degrades to plain byte-level. Round-trip is lossless for
//! any input (property-tested).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
    /// merges[i] = (left token, right token) producing token 256 + i
    pub merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
}

impl Tokenizer {
    /// Byte-level tokenizer (vocab exactly 256).
    pub fn byte_level() -> Self {
        Tokenizer { vocab: 256, merges: Vec::new(), rank: HashMap::new() }
    }

    /// Train greedy bigram merges on `text` up to `vocab` entries.
    pub fn train(text: &str, vocab: usize) -> Self {
        assert!(vocab >= 256, "vocab must hold all bytes");
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        let mut merges = Vec::new();
        while merges.len() + 256 < vocab {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by_key(|&(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = 256 + merges.len() as u32;
            merges.push(pair);
            toks = merge_pass(&toks, pair, new_id);
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, 256 + i as u32))
            .collect();
        Tokenizer { vocab, merges, rank }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        // Apply merges in training order (rank order = priority order).
        for (i, &pair) in self.merges.iter().enumerate() {
            let id = 256 + i as u32;
            if toks.len() < 2 {
                break;
            }
            toks = merge_pass(&toks, pair, id);
        }
        toks
    }

    pub fn decode(&self, toks: &[u32]) -> String {
        String::from_utf8_lossy(&self.decode_bytes(toks)).into_owned()
    }

    /// Lossless byte-level decode — the streaming path uses this so a
    /// multi-byte character split across tokens survives intact (the
    /// UTF-8-lossy conversion must happen once over the full sequence,
    /// never per token).
    pub fn decode_bytes(&self, toks: &[u32]) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(toks.len() * 2);
        for &t in toks {
            self.expand(t, &mut bytes);
        }
        bytes
    }

    fn expand(&self, t: u32, out: &mut Vec<u8>) {
        if t < 256 {
            out.push(t as u8);
        } else {
            let (a, b) = self.merges[(t - 256) as usize];
            self.expand(a, out);
            self.expand(b, out);
        }
    }

    /// Fast path when no merge applies to the pair.
    pub fn has_merge(&self, a: u32, b: u32) -> bool {
        self.rank.contains_key(&(a, b))
    }

    /// Serialize to the checkpoint text format: a header line with the
    /// vocab size, then one `left right` pair per merge in rank order.
    pub fn to_text(&self) -> String {
        let mut out = format!("chon-tokenizer v1 vocab={}\n", self.vocab);
        for &(a, b) in &self.merges {
            out.push_str(&format!("{a} {b}\n"));
        }
        out
    }

    /// Parse the checkpoint text format back into a tokenizer.
    pub fn from_text(text: &str) -> Result<Tokenizer, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty tokenizer file")?;
        let vocab: usize = header
            .strip_prefix("chon-tokenizer v1 vocab=")
            .ok_or_else(|| format!("bad tokenizer header {header:?}"))?
            .trim()
            .parse()
            .map_err(|e| format!("bad vocab in tokenizer header: {e}"))?;
        let mut merges = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let parse = |tok: Option<&str>| -> Result<u32, String> {
                tok.ok_or_else(|| format!("short merge line {}", i + 2))?
                    .parse()
                    .map_err(|e| format!("bad merge line {}: {e}", i + 2))
            };
            let pair = (parse(it.next())?, parse(it.next())?);
            // merges only reference bytes or previously defined merges
            let limit = 256 + merges.len() as u32;
            if pair.0 >= limit || pair.1 >= limit {
                return Err(format!("merge line {} references undefined token", i + 2));
            }
            merges.push(pair);
        }
        if vocab < 256 + merges.len() {
            return Err(format!(
                "tokenizer vocab {vocab} smaller than 256 + {} merges",
                merges.len()
            ));
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, 256 + i as u32))
            .collect();
        Ok(Tokenizer { vocab, merges, rank })
    }
}

fn merge_pass(toks: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if i + 1 < toks.len() && (toks[i], toks[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(toks[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    #[test]
    fn byte_level_roundtrip() {
        let t = Tokenizer::byte_level();
        let s = "hello, NVFP4 world! \x01\x7f";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.encode(s).len(), s.len());
    }

    #[test]
    fn trained_roundtrip_lossless() {
        let c = Corpus::new(CorpusConfig::default());
        let train = c.generate(20_000, 0);
        let t = Tokenizer::train(&train, 512);
        assert!(!t.merges.is_empty());
        for seed in 1..4 {
            let s = c.generate(5_000, seed);
            assert_eq!(t.decode(&t.encode(&s)), s, "roundtrip seed {seed}");
        }
    }

    #[test]
    fn merges_compress() {
        let c = Corpus::new(CorpusConfig::default());
        let text = c.generate(20_000, 0);
        let t = Tokenizer::train(&text, 512);
        let toks = t.encode(&text);
        assert!(
            toks.len() < text.len() * 8 / 10,
            "compression {} / {}",
            toks.len(),
            text.len()
        );
        assert!(toks.iter().all(|&x| (x as usize) < t.vocab));
    }

    #[test]
    fn text_serialization_roundtrip() {
        let c = Corpus::new(CorpusConfig::default());
        let t = Tokenizer::train(&c.generate(10_000, 0), 320);
        let back = Tokenizer::from_text(&t.to_text()).unwrap();
        assert_eq!(back.vocab, t.vocab);
        assert_eq!(back.merges, t.merges);
        let s = c.generate(2_000, 7);
        assert_eq!(back.encode(&s), t.encode(&s));

        let byte = Tokenizer::byte_level();
        let back = Tokenizer::from_text(&byte.to_text()).unwrap();
        assert_eq!(back.vocab, 256);
        assert!(back.merges.is_empty());
    }

    #[test]
    fn malformed_tokenizer_text_rejected() {
        assert!(Tokenizer::from_text("").is_err());
        assert!(Tokenizer::from_text("bogus header\n1 2\n").is_err());
        // merge referencing a not-yet-defined token id
        assert!(
            Tokenizer::from_text("chon-tokenizer v1 vocab=300\n900 1\n").is_err()
        );
        // vocab too small for the merge list
        assert!(
            Tokenizer::from_text("chon-tokenizer v1 vocab=256\n97 98\n").is_err()
        );
    }

    #[test]
    fn tokens_within_vocab() {
        let t = Tokenizer::train("abababab cdcdcdcd", 260);
        for tok in t.encode("abcdabcd xyz") {
            assert!((tok as usize) < t.vocab);
        }
    }
}
