//! Byte-level tokenizer with a greedy bigram-merge vocabulary (micro-BPE).
//!
//! Vocab layout: [0..256) raw bytes, [256..vocab) learned merges. A 256-
//! entry vocab degrades to plain byte-level. Round-trip is lossless for
//! any input (property-tested).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
    /// merges[i] = (left token, right token) producing token 256 + i
    pub merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
}

impl Tokenizer {
    /// Byte-level tokenizer (vocab exactly 256).
    pub fn byte_level() -> Self {
        Tokenizer { vocab: 256, merges: Vec::new(), rank: HashMap::new() }
    }

    /// Train greedy bigram merges on `text` up to `vocab` entries.
    pub fn train(text: &str, vocab: usize) -> Self {
        assert!(vocab >= 256, "vocab must hold all bytes");
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        let mut merges = Vec::new();
        while merges.len() + 256 < vocab {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in toks.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by_key(|&(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = 256 + merges.len() as u32;
            merges.push(pair);
            toks = merge_pass(&toks, pair, new_id);
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, 256 + i as u32))
            .collect();
        Tokenizer { vocab, merges, rank }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut toks: Vec<u32> = text.bytes().map(u32::from).collect();
        // Apply merges in training order (rank order = priority order).
        for (i, &pair) in self.merges.iter().enumerate() {
            let id = 256 + i as u32;
            if toks.len() < 2 {
                break;
            }
            toks = merge_pass(&toks, pair, id);
        }
        toks
    }

    pub fn decode(&self, toks: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(toks.len() * 2);
        for &t in toks {
            self.expand(t, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn expand(&self, t: u32, out: &mut Vec<u8>) {
        if t < 256 {
            out.push(t as u8);
        } else {
            let (a, b) = self.merges[(t - 256) as usize];
            self.expand(a, out);
            self.expand(b, out);
        }
    }

    /// Fast path when no merge applies to the pair.
    pub fn has_merge(&self, a: u32, b: u32) -> bool {
        self.rank.contains_key(&(a, b))
    }
}

fn merge_pass(toks: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if i + 1 < toks.len() && (toks[i], toks[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(toks[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    #[test]
    fn byte_level_roundtrip() {
        let t = Tokenizer::byte_level();
        let s = "hello, NVFP4 world! \x01\x7f";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.encode(s).len(), s.len());
    }

    #[test]
    fn trained_roundtrip_lossless() {
        let c = Corpus::new(CorpusConfig::default());
        let train = c.generate(20_000, 0);
        let t = Tokenizer::train(&train, 512);
        assert!(!t.merges.is_empty());
        for seed in 1..4 {
            let s = c.generate(5_000, seed);
            assert_eq!(t.decode(&t.encode(&s)), s, "roundtrip seed {seed}");
        }
    }

    #[test]
    fn merges_compress() {
        let c = Corpus::new(CorpusConfig::default());
        let text = c.generate(20_000, 0);
        let t = Tokenizer::train(&text, 512);
        let toks = t.encode(&text);
        assert!(
            toks.len() < text.len() * 8 / 10,
            "compression {} / {}",
            toks.len(),
            text.len()
        );
        assert!(toks.iter().all(|&x| (x as usize) < t.vocab));
    }

    #[test]
    fn tokens_within_vocab() {
        let t = Tokenizer::train("abababab cdcdcdcd", 260);
        for tok in t.encode("abcdabcd xyz") {
            assert!((tok as usize) < t.vocab);
        }
    }
}
