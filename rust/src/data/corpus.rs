//! Synthetic corpus generator — the RedPajama substitute (DESIGN.md
//! §Substitutions).
//!
//! Produces byte-level text with learnable structure at several scales so
//! a small LM's loss actually decreases:
//!   * Zipf-distributed word vocabulary (natural-language rank law)
//!   * order-2 Markov chain over words (local predictability)
//!   * templated "facts" with deterministic continuations, reused by the
//!     downstream cloze eval suite (the Tab. 1 substitute)

use crate::util::prng::{Rng, Zipf};

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub n_words: usize,
    pub zipf_s: f64,
    pub n_facts: usize,
    pub fact_every: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { n_words: 512, zipf_s: 1.1, n_facts: 64, fact_every: 24, seed: 0 }
    }
}

/// A templated fact: "<subject> is <object>." — subject determines object
/// deterministically, so a trained model can be cloze-tested on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fact {
    pub subject: String,
    pub object: String,
}

pub struct Corpus {
    cfg: CorpusConfig,
    words: Vec<String>,
    zipf: Zipf,
    /// markov[w] = the 4 preferred successors of word w
    markov: Vec<[usize; 4]>,
    pub facts: Vec<Fact>,
}

const SYLLABLES: [&str; 16] = [
    "ka", "to", "mi", "ren", "shu", "bel", "or", "da", "vin", "lu", "pe",
    "gor", "sa", "ti", "mon", "ze",
];

fn make_word(rng: &mut Rng) -> String {
    let n = 2 + rng.below(2);
    (0..n).map(|_| SYLLABLES[rng.below(SYLLABLES.len())]).collect()
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut words = Vec::with_capacity(cfg.n_words);
        while words.len() < cfg.n_words {
            let w = make_word(&mut rng);
            if !words.contains(&w) {
                words.push(w);
            }
        }
        let markov = (0..cfg.n_words)
            .map(|_| {
                [
                    rng.below(cfg.n_words),
                    rng.below(cfg.n_words),
                    rng.below(cfg.n_words),
                    rng.below(cfg.n_words),
                ]
            })
            .collect();
        let mut facts = Vec::with_capacity(cfg.n_facts);
        let mut srng = rng.fold_in(0xFAC7);
        while facts.len() < cfg.n_facts {
            let s = make_word(&mut srng);
            let o = make_word(&mut srng);
            if !facts.iter().any(|f: &Fact| f.subject == s) {
                facts.push(Fact { subject: s, object: o });
            }
        }
        let zipf = Zipf::new(cfg.n_words, cfg.zipf_s);
        Corpus { cfg, words, zipf, markov, facts }
    }

    /// Generate `n_bytes` of corpus text, deterministic in (config, seed).
    pub fn generate(&self, n_bytes: usize, stream_seed: u64) -> String {
        let mut rng = Rng::new(self.cfg.seed ^ stream_seed.wrapping_mul(0x9E37));
        let mut out = String::with_capacity(n_bytes + 64);
        let mut prev = self.zipf.sample(&mut rng);
        let mut since_fact = 0usize;
        while out.len() < n_bytes {
            since_fact += 1;
            if since_fact >= self.cfg.fact_every && !self.facts.is_empty() {
                since_fact = 0;
                let f = &self.facts[rng.below(self.facts.len())];
                out.push_str(&f.subject);
                out.push_str(" is ");
                out.push_str(&f.object);
                out.push_str(". ");
                continue;
            }
            // 70%: Markov successor; 30%: fresh Zipf draw
            let w = if rng.uniform() < 0.7 {
                self.markov[prev][rng.below(4)]
            } else {
                self.zipf.sample(&mut rng)
            };
            out.push_str(&self.words[w]);
            prev = w;
            if rng.uniform() < 0.12 {
                out.push_str(". ");
            } else {
                out.push(' ');
            }
        }
        out.truncate(n_bytes);
        out
    }

    /// Cloze prompts for the eval suite: ("<subject> is ", "<object>").
    pub fn cloze_pairs(&self) -> Vec<(String, String)> {
        self.facts
            .iter()
            .map(|f| (format!("{} is ", f.subject), f.object.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c1 = Corpus::new(CorpusConfig::default());
        let c2 = Corpus::new(CorpusConfig::default());
        assert_eq!(c1.generate(4096, 7), c2.generate(4096, 7));
        assert_eq!(c1.facts, c2.facts);
    }

    #[test]
    fn different_seeds_differ() {
        let c1 = Corpus::new(CorpusConfig::default());
        let c2 = Corpus::new(CorpusConfig { seed: 1, ..CorpusConfig::default() });
        assert_ne!(c1.generate(1024, 0), c2.generate(1024, 0));
    }

    #[test]
    fn exact_length_and_ascii() {
        let c = Corpus::new(CorpusConfig::default());
        let s = c.generate(10_000, 3);
        assert_eq!(s.len(), 10_000);
        assert!(s.is_ascii());
    }

    #[test]
    fn facts_embedded_in_stream() {
        let c = Corpus::new(CorpusConfig { fact_every: 4, ..CorpusConfig::default() });
        let s = c.generate(50_000, 1);
        let mut found = 0;
        for f in &c.facts {
            if s.contains(&format!("{} is {}", f.subject, f.object)) {
                found += 1;
            }
        }
        assert!(found > c.facts.len() / 4, "only {found} facts found");
    }

    #[test]
    fn compressible_structure() {
        // Markov + Zipf text must have much lower byte entropy than random.
        let c = Corpus::new(CorpusConfig::default());
        let s = c.generate(100_000, 2);
        let mut counts = [0usize; 256];
        for &b in s.as_bytes() {
            counts[b as usize] += 1;
        }
        let n = s.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        assert!(h < 5.0, "byte entropy {h} too high");
    }
}
