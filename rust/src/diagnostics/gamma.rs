//! Checkpoint-level analyses: RMSNorm γ distributions (Fig. 29/30,
//! App. E.8) and lm_head representational overlap / superposition
//! (Fig. 31, App. E.9).

use crate::util::ndarray::Mat;

/// Summary of one RMSNorm scale vector γ.
#[derive(Clone, Copy, Debug)]
pub struct GammaStats {
    pub mean: f64,
    pub max: f64,
    /// fraction of channels with γ > 1 (the SA-vs-LA discriminator)
    pub frac_above_one: f64,
}

/// Analyze one γ vector.
pub fn gamma_stats(gamma: &[f32]) -> GammaStats {
    let n = gamma.len().max(1) as f64;
    let mean = gamma.iter().map(|&v| v as f64).sum::<f64>() / n;
    let max = gamma.iter().fold(f64::MIN, |m, &v| m.max(v as f64));
    let above = gamma.iter().filter(|&&v| v > 1.0).count() as f64 / n;
    GammaStats { mean, max, frac_above_one: above }
}

/// Depth trend of γ means: simple least-squares slope over layer index
/// (Fig. 30 observation (i): |γ| grows with depth in SA models).
pub fn gamma_depth_slope(per_layer_means: &[f64]) -> f64 {
    let n = per_layer_means.len();
    if n < 2 {
        return 0.0;
    }
    let xm = (n as f64 - 1.0) / 2.0;
    let ym = per_layer_means.iter().sum::<f64>() / n as f64;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in per_layer_means.iter().enumerate() {
        let dx = i as f64 - xm;
        num += dx * (y - ym);
        den += dx * dx;
    }
    num / den.max(1e-30)
}

/// Weight overlap magnitude (Fig. 31): squared Frobenius norm of the
/// off-diagonal of the row-normalized Gram matrix of `w` (rows =
/// representation vectors), divided by the number of off-diagonal
/// entries. 0 = orthogonal features; grows with superposition density.
pub fn weight_overlap(w: &Mat) -> f64 {
    let r = w.rows;
    if r < 2 {
        return 0.0;
    }
    // row norms
    let norms: Vec<f64> = (0..r)
        .map(|i| {
            w.row(i)
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum::<f64>()
                .sqrt()
                .max(1e-30)
        })
        .collect();
    let mut acc = 0.0;
    for i in 0..r {
        for j in (i + 1)..r {
            let dot: f64 = w
                .row(i)
                .iter()
                .zip(w.row(j))
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            let c = dot / (norms[i] * norms[j]);
            acc += 2.0 * c * c; // count (i,j) and (j,i)
        }
    }
    acc / (r * (r - 1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn gamma_stats_basic() {
        let s = gamma_stats(&[0.5, 1.5, 2.0, 0.9]);
        assert!((s.mean - 1.225).abs() < 1e-6);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.frac_above_one, 0.5);
    }

    #[test]
    fn depth_slope_direction() {
        assert!(gamma_depth_slope(&[1.0, 1.2, 1.4, 1.9]) > 0.0);
        assert!(gamma_depth_slope(&[2.0, 1.5, 1.0]) < 0.0);
        assert_eq!(gamma_depth_slope(&[1.0]), 0.0);
    }

    #[test]
    fn orthogonal_rows_have_zero_overlap() {
        let eye = Mat::from_fn(8, 8, |r, c| if r == c { 1.0 } else { 0.0 });
        assert!(weight_overlap(&eye) < 1e-12);
    }

    #[test]
    fn identical_rows_have_unit_overlap() {
        let ones = Mat::from_fn(4, 8, |_, c| (c as f32 + 1.0).sin());
        assert!((weight_overlap(&ones) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_rows_between_extremes_and_shrink_with_width() {
        let mut rng = Rng::new(3);
        let narrow = Mat::from_fn(32, 16, |_, _| rng.normal());
        let wide = Mat::from_fn(32, 256, |_, _| rng.normal());
        let on = weight_overlap(&narrow);
        let ow = weight_overlap(&wide);
        // E[cos^2] = 1/d for random vectors: wider space -> lower overlap
        assert!(on > ow, "narrow {on} vs wide {ow}");
        assert!((on - 1.0 / 16.0).abs() < 0.03);
        assert!((ow - 1.0 / 256.0).abs() < 0.003);
    }
}
