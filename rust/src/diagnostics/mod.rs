//! Outlier diagnostics substrate — every indicator of Sec. 3 / App. E,
//! natively in Rust so the coordinator can analyze checkpoints and
//! activations on the request path.
//!
//! * kurtosis (Eq. 1), per-tensor and per-16×16-block (Fig. 1/4/5/17/18)
//! * top-k magnitude + per-channel hot-channel maps (Fig. 3/6/20/21/22)
//! * flush-to-zero ratio (Sec. 3 FTZ; Fig. 26/27)
//! * softmax entropy + pre-softmax stats (Fig. 7)
//! * SwiGLU weight cosine alignment (Fig. 8)
//! * quantization-error MSE (Fig. 32), Frobenius energy (App. E.5)

pub mod gamma;

use crate::quant::nvfp4;
use crate::util::ndarray::Mat;

/// Excess kurtosis (Eq. 1) with f64 accumulation.
pub fn kurtosis(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut m2 = 0.0;
    let mut m4 = 0.0;
    for &v in x {
        let d = v as f64 - mean;
        let d2 = d * d;
        m2 += d2;
        m4 += d2 * d2;
    }
    m2 /= n;
    m4 /= n;
    if m2 <= 1e-30 {
        return 0.0;
    }
    m4 / (m2 * m2) - 3.0
}

/// Summary of a per-block statistic map (the Fig. 4 min/avg/max triplet).
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockSummary {
    pub min: f64,
    pub avg: f64,
    pub max: f64,
}

/// Per-(bm×bn)-block kurtosis of a matrix; ragged edges truncated.
pub fn block_kurtosis(x: &Mat, bm: usize, bn: usize) -> Vec<f64> {
    let rb = x.rows / bm;
    let cb = x.cols / bn;
    let mut out = Vec::with_capacity(rb * cb);
    let mut buf = vec![0.0f32; bm * bn];
    for i in 0..rb {
        for j in 0..cb {
            let mut p = 0;
            for r in i * bm..(i + 1) * bm {
                let row = x.row(r);
                buf[p..p + bn].copy_from_slice(&row[j * bn..(j + 1) * bn]);
                p += bn;
            }
            out.push(kurtosis(&buf));
        }
    }
    out
}

pub fn summarize(vals: &[f64]) -> BlockSummary {
    if vals.is_empty() {
        return BlockSummary::default();
    }
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut sum = 0.0;
    for &v in vals {
        min = min.min(v);
        max = max.max(v);
        sum += v;
    }
    BlockSummary { min, avg: sum / vals.len() as f64, max }
}

/// Top-k magnitudes over a flat tensor, descending.
pub fn topk_magnitude(x: &[f32], k: usize) -> Vec<f32> {
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let k = k.min(mags.len());
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    mags.truncate(k);
    mags
}

/// Per-channel (column) max magnitude — the hot-channel map of Fig. 3.
pub fn channel_max(x: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; x.cols];
    for r in 0..x.rows {
        for (c, &v) in x.row(r).iter().enumerate() {
            out[c] = out[c].max(v.abs());
        }
    }
    out
}

/// Top-k hot channels (indices + magnitudes) from a channel map.
pub fn hot_channels(chan: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..chan.len()).collect();
    idx.sort_by(|&a, &b| {
        chan[b].partial_cmp(&chan[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k.min(chan.len()));
    idx.into_iter().map(|i| (i, chan[i])).collect()
}

/// Jaccard overlap of two hot-channel index sets — the drift/persistence
/// measure behind "transient spikes -> fixed hot channels" (Sec. 3.3).
pub fn channel_overlap(a: &[(usize, f32)], b: &[(usize, f32)]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let sa: std::collections::HashSet<usize> = a.iter().map(|&(i, _)| i).collect();
    let sb: std::collections::HashSet<usize> = b.iter().map(|&(i, _)| i).collect();
    let inter = sa.intersection(&sb).count() as f64;
    let union = sa.union(&sb).count() as f64;
    inter / union
}

/// NVFP4 flush-to-zero ratio of a tensor.
pub fn ftz(x: &[f32]) -> f64 {
    nvfp4::ftz_ratio(x)
}

/// NVFP4 quantization MSE of a tensor.
pub fn quant_mse(x: &[f32]) -> f64 {
    nvfp4::quant_mse(x)
}

/// Frobenius energy ‖X‖²_F (App. E.5).
pub fn frobenius_energy(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Mean softmax entropy over rows of a logits matrix (Fig. 7).
pub fn softmax_entropy(logits: &Mat) -> f64 {
    let mut total = 0.0;
    for r in 0..logits.rows {
        let row = logits.row(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f64;
        for &v in row {
            z += ((v - max) as f64).exp();
        }
        let logz = z.ln() + max as f64;
        let mut h = 0.0;
        for &v in row {
            let logp = v as f64 - logz;
            h -= logp.exp() * logp;
        }
        total += h;
    }
    total / logits.rows as f64
}

/// Mean |cos| alignment between paired rows of two matrices (Fig. 8).
pub fn cosine_alignment(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut total = 0.0;
    for r in 0..a.rows {
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for (&x, &y) in a.row(r).iter().zip(b.row(r)) {
            dot += x as f64 * y as f64;
            na += x as f64 * x as f64;
            nb += y as f64 * y as f64;
        }
        total += dot.abs() / (na.sqrt() * nb.sqrt()).max(1e-30);
    }
    total / a.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn kurtosis_reference_distributions() {
        let mut rng = Rng::new(1);
        let g: Vec<f32> = (0..200_000).map(|_| rng.normal()).collect();
        assert!(kurtosis(&g).abs() < 0.15, "gaussian {}", kurtosis(&g));
        let l: Vec<f32> = (0..200_000).map(|_| rng.laplace(1.0)).collect();
        assert!((kurtosis(&l) - 3.0).abs() < 0.5, "laplace {}", kurtosis(&l));
        let u: Vec<f32> = (0..200_000).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        assert!((kurtosis(&u) + 1.2).abs() < 0.1, "uniform {}", kurtosis(&u));
    }

    #[test]
    fn outlier_raises_kurtosis() {
        let mut rng = Rng::new(2);
        let mut x: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        let k0 = kurtosis(&x);
        x[0] = 100.0;
        assert!(kurtosis(&x) > k0 + 100.0);
    }

    #[test]
    fn block_kurtosis_localizes() {
        let mut rng = Rng::new(3);
        let mut m = Mat::from_fn(64, 64, |_, _| rng.normal());
        *m.at_mut(3, 5) = 100.0;
        let bk = block_kurtosis(&m, 16, 16);
        assert_eq!(bk.len(), 16);
        let s = summarize(&bk);
        assert!(s.max > 50.0);
        assert_eq!(bk[0], s.max, "outlier in block (0,0)");
        assert!(bk[1].abs() < 3.0);
    }

    #[test]
    fn topk_and_channels() {
        let m = Mat::from_vec(2, 4, vec![1., -7., 0.5, 2., 3., 0.1, 0.2, -2.]);
        assert_eq!(topk_magnitude(&m.data, 2), vec![7.0, 3.0]);
        let ch = channel_max(&m);
        assert_eq!(ch, vec![3.0, 7.0, 0.5, 2.0]);
        let hot = hot_channels(&ch, 2);
        assert_eq!(hot[0].0, 1);
        assert_eq!(hot[1].0, 0);
    }

    #[test]
    fn overlap_measures_persistence() {
        let a = vec![(1usize, 1.0f32), (2, 0.9), (3, 0.8)];
        let b = vec![(1usize, 1.1f32), (2, 0.7), (9, 0.6)];
        let j = channel_overlap(&a, &b);
        assert!((j - 0.5).abs() < 1e-9); // |{1,2}| / |{1,2,3,9}|
        assert_eq!(channel_overlap(&a, &a), 1.0);
    }

    #[test]
    fn entropy_bounds() {
        let uni = Mat::zeros(4, 64);
        assert!((softmax_entropy(&uni) - (64f64).ln()).abs() < 1e-9);
        let mut sharp = Mat::zeros(4, 64);
        for r in 0..4 {
            *sharp.at_mut(r, 0) = 100.0;
        }
        assert!(softmax_entropy(&sharp) < 1e-3);
    }

    #[test]
    fn alignment_identity_and_random() {
        let mut rng = Rng::new(4);
        let a = Mat::from_fn(32, 64, |_, _| rng.normal());
        assert!((cosine_alignment(&a, &a) - 1.0).abs() < 1e-9);
        let b = Mat::from_fn(32, 64, |_, _| rng.normal());
        assert!(cosine_alignment(&a, &b) < 0.3);
    }

    #[test]
    fn frobenius_energy_known() {
        assert_eq!(frobenius_energy(&[3.0, 4.0]), 25.0);
    }
}
