//! Post-training flow (App. D.1 analogue): continue training a
//! pretrained checkpoint on a *shifted* data distribution (new corpus
//! seed = the "SFT dataset") under different precisions, and track the
//! NVFP4-vs-BF16 loss-gap trajectory (the Fig. 15c readout — the gap
//! widening during decay is the paper's SFT observation).

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::metrics::loss_gap_pct;
use crate::coordinator::trainer::Trainer;
use crate::info;

/// One probe of the fine-tuning gap trajectory.
#[derive(Clone, Copy, Debug)]
pub struct GapPoint {
    pub step: usize,
    pub bf16_loss: f32,
    pub quant_loss: f32,
    pub gap_pct: f64,
}

/// Pretrain for `pretrain_steps` (bf16), checkpoint, then fine-tune the
/// same initial state under bf16 and `quant_recipe` on a shifted corpus;
/// returns the gap trajectory sampled every `probe_every` steps.
pub fn finetune_gap_study(
    base: &RunConfig,
    quant_recipe: &str,
    pretrain_steps: usize,
    finetune_steps: usize,
    probe_every: usize,
) -> Result<Vec<GapPoint>> {
    // Phase 1: pretrain in BF16 on the base corpus.
    let mut cfg = base.clone();
    cfg.recipe = "bf16".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    let mut pre = Trainer::new(cfg.clone())?;
    pre.train(pretrain_steps)?;
    let ckpt_dir = base.out_dir.join("finetune_ckpt");
    let ckpt = pre.save_checkpoint_to(&ckpt_dir)?;
    info!(
        "finetune: pretrained {} steps (loss {:.4}), checkpoint {}",
        pretrain_steps,
        pre.log.final_loss().unwrap(),
        ckpt.display()
    );

    // Phase 2: fine-tune from the checkpoint on a shifted distribution.
    let mut mk = |recipe: &str| -> Result<Trainer> {
        let mut c = cfg.clone();
        c.recipe = recipe.into();
        c.seed = base.seed + 10_007; // shifted corpus = the "SFT" dataset
        let mut tr = Trainer::new(c)?;
        tr.load_params(&ckpt)?;
        Ok(tr)
    };
    let mut ft_bf16 = mk("bf16")?;
    let mut ft_quant = mk(quant_recipe)?;

    let mut out = Vec::new();
    let mut done = 0;
    while done < finetune_steps {
        let chunk = probe_every.min(finetune_steps - done);
        ft_bf16.train(chunk)?;
        ft_quant.train(chunk)?;
        done += chunk;
        let lb = ft_bf16.log.tail_mean_loss(5).unwrap();
        let lq = ft_quant.log.tail_mean_loss(5).unwrap();
        let p = GapPoint {
            step: done,
            bf16_loss: lb,
            quant_loss: lq,
            gap_pct: loss_gap_pct(lq, lb),
        };
        info!(
            "finetune @{}: bf16 {:.4} vs {quant_recipe} {:.4} -> gap {:+.3}%",
            p.step, p.bf16_loss, p.quant_loss, p.gap_pct
        );
        out.push(p);
    }
    Ok(out)
}

pub fn print_gap_trajectory(recipe: &str, points: &[GapPoint]) {
    println!("\nFig. 15c (substitute) — fine-tuning loss gap ({recipe} vs bf16)");
    println!("{:>8} {:>12} {:>12} {:>10}", "step", "bf16", recipe, "gap %");
    for p in points {
        println!(
            "{:>8} {:>12.4} {:>12.4} {:>+10.3}",
            p.step, p.bf16_loss, p.quant_loss, p.gap_pct
        );
    }
}
