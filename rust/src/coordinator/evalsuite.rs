//! Downstream eval suite — the lm-eval-harness substitute (DESIGN.md
//! §Substitutions, Tab. 1/8).
//!
//! Tasks:
//!  * cloze: the corpus embeds deterministic facts "<subject> is
//!    <object>."; we teacher-force the fact through the fwd artifact and
//!    score per-token accuracy on the object span.
//!  * heldout: loss/accuracy on fresh corpus batches via the eval artifact.

use anyhow::{bail, Result};

use crate::coordinator::trainer::Trainer;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::tokenizer::Tokenizer;
use crate::info;
use crate::runtime::{backend_for, Executable, HostTensor};

/// Scores for one recipe checkpoint.
#[derive(Clone, Debug)]
pub struct EvalScores {
    pub recipe: String,
    pub cloze_acc: f64,
    pub heldout_loss: f32,
    pub heldout_acc: f32,
}

/// Teacher-forced cloze accuracy over the corpus facts.
///
/// For each fact, the byte sequence "<subject> is <object>." is packed
/// into a (batch, seq) window; accuracy counts next-token hits on the
/// object span only.
pub fn cloze_accuracy(
    fwd: &dyn Executable,
    params: &[HostTensor],
    seed: u64,
) -> Result<f64> {
    let man = fwd.manifest();
    let batch = man.meta_usize("batch")?;
    let seq = man.meta_usize("seq_len")?;
    let vocab = man.meta_usize("vocab")?;
    let corpus = Corpus::new(CorpusConfig { seed, ..CorpusConfig::default() });
    let tok = Tokenizer::byte_level(); // facts are scored at byte level
    let mut hits = 0usize;
    let mut total = 0usize;

    let facts = corpus.cloze_pairs();
    let mut fi = 0;
    while fi < facts.len() {
        // pack up to `batch` facts into one forward call
        let mut tokens = vec![32i32; batch * seq]; // pad with spaces
        let mut spans: Vec<(usize, usize, Vec<u32>)> = Vec::new(); // row, prompt_len, object toks
        for row in 0..batch {
            if fi >= facts.len() {
                break;
            }
            let (prompt, object) = &facts[fi];
            fi += 1;
            let p: Vec<u32> = tok.encode(prompt).iter().map(|&t| t % vocab as u32).collect();
            let o: Vec<u32> = tok.encode(object).iter().map(|&t| t % vocab as u32).collect();
            if p.len() + o.len() + 1 > seq {
                continue;
            }
            for (i, &t) in p.iter().chain(o.iter()).enumerate() {
                tokens[row * seq + i] = t as i32;
            }
            spans.push((row, p.len(), o));
        }
        if spans.is_empty() {
            continue;
        }
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::i32(vec![batch, seq], tokens.clone()));
        let out = fwd.run(&inputs)?;
        let logits = &out[0]; // (batch, seq, vocab)
        if logits.shape != vec![batch, seq, vocab] {
            bail!("unexpected fwd output shape {:?}", logits.shape);
        }
        for (row, plen, object) in spans {
            for (j, &want) in object.iter().enumerate() {
                // prediction at position plen+j-1 targets token plen+j
                let pos = plen + j - 1 + 1 - 1; // = plen + j - 1
                let base = (row * seq + pos) * vocab;
                let slice = &logits.f32_data[base..base + vocab];
                let argmax = slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                total += 1;
                if argmax == want as usize {
                    hits += 1;
                }
            }
        }
    }
    if total == 0 {
        bail!("no cloze spans fit the sequence length");
    }
    Ok(hits as f64 / total as f64)
}

/// Train a fresh model per recipe and evaluate it (the Tab. 1 substitute).
pub fn run_suite(
    base: &crate::config::RunConfig,
    recipes: &[String],
    steps: usize,
) -> Result<Vec<EvalScores>> {
    let backend = backend_for(&base.backend)?;
    let fwd = backend.load(&base.artifacts, &format!("fwd_{}", base.model))?;
    let mut out = Vec::new();
    for recipe in recipes {
        let mut cfg = base.clone();
        cfg.recipe = recipe.clone();
        cfg.diag_every = 0;
        cfg.eval_every = 0;
        let mut tr = Trainer::new(cfg)?;
        tr.train(steps)?;
        let (heldout_loss, heldout_acc) = tr.evaluate(4)?;
        let cloze = cloze_accuracy(fwd.as_ref(), &tr.state.params, base.seed)?;
        info!(
            "eval-suite {recipe}: cloze {cloze:.3} heldout loss {heldout_loss:.4} acc {heldout_acc:.3}"
        );
        out.push(EvalScores {
            recipe: recipe.clone(),
            cloze_acc: cloze,
            heldout_loss,
            heldout_acc,
        });
    }
    Ok(out)
}

pub fn print_suite(rows: &[EvalScores]) {
    println!("\nTable 1 (substitute) — downstream eval across recipes");
    println!(
        "{:<14} {:>12} {:>14} {:>13}",
        "Setting", "Cloze Acc", "Heldout Loss", "Heldout Acc"
    );
    for r in rows {
        println!(
            "{:<14} {:>12.3} {:>14.4} {:>13.3}",
            r.recipe, r.cloze_acc, r.heldout_loss, r.heldout_acc
        );
    }
}
