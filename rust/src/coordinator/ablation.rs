//! Ablation runners: the Tab. 2 recipe grid and the Tab. 3 operator
//! sensitivity study, driven entirely from Rust over the AOT artifacts.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::coordinator::metrics::loss_gap_pct;
use crate::coordinator::trainer::Trainer;
use crate::info;
use crate::runtime::Manifest;

/// One Tab. 2 row.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub recipe: String,
    pub final_loss: f32,
    pub gap_pct: f64,
}

/// Train every recipe in `recipes` for `steps` with identical data/seed;
/// report final losses sorted by ascending gap to the bf16 baseline.
pub fn table2(
    base: &RunConfig,
    recipes: &[String],
    steps: usize,
    tail: usize,
) -> Result<Vec<Table2Row>> {
    let mut losses = Vec::new();
    for recipe in recipes {
        let mut cfg = base.clone();
        cfg.recipe = recipe.clone();
        cfg.diag_every = 0;
        cfg.eval_every = 0;
        let mut tr = Trainer::new(cfg)
            .with_context(|| format!("building trainer for {recipe}"))?;
        tr.train(steps)?;
        let loss = tr.log.tail_mean_loss(tail).unwrap();
        info!("table2: {recipe} -> final loss {loss:.6}");
        tr.write_outputs()?;
        losses.push((recipe.clone(), loss));
    }
    let baseline = losses
        .iter()
        .find(|(r, _)| r == "bf16")
        .map(|&(_, l)| l)
        .unwrap_or_else(|| losses[0].1);
    let mut rows: Vec<Table2Row> = losses
        .into_iter()
        .map(|(recipe, final_loss)| Table2Row {
            recipe,
            final_loss,
            gap_pct: loss_gap_pct(final_loss, baseline),
        })
        .collect();
    rows.sort_by(|a, b| a.gap_pct.partial_cmp(&b.gap_pct).unwrap());
    Ok(rows)
}

pub fn write_table2(rows: &[Table2Row], path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "configuration,final_loss,loss_gap_pct")?;
    for r in rows {
        writeln!(f, "{},{:.6},{:.3}", r.recipe, r.final_loss, r.gap_pct)?;
    }
    Ok(())
}

pub fn print_table2(rows: &[Table2Row]) {
    println!("\nTable 2 — final loss and relative gap to BF16 (sorted)");
    println!("{:<28} {:>12} {:>14}", "Configuration", "Final Loss", "Loss Gap (%)");
    for r in rows {
        println!("{:<28} {:>12.6} {:>14.3}", r.recipe, r.final_loss, r.gap_pct);
    }
}

/// One Tab. 3 row: per-operator quantization sensitivity.
#[derive(Clone, Debug)]
pub struct Table3Row {
    pub op: String,
    pub delta_loss: f64,
    pub op_params: usize,
    /// ΔLoss / params ×1e6 (the parameter-normalized sensitivity score)
    pub score: f64,
}

/// Parameter count of the weight backing one operator, from the manifest.
fn op_param_count(man: &Manifest, op: &str) -> usize {
    let pname = match op {
        "attn.q" => "wq",
        "attn.k" => "wk",
        "attn.v" => "wv",
        "attn.o" => "wo",
        "attn.gk" => "wgk",
        "attn.g" => "wg",
        "mlp.up" => "w_up",
        "mlp.gate" => "w_gate",
        "mlp.down" => "w_down",
        _ => return 0,
    };
    man.inputs
        .iter()
        .filter(|s| s.name.contains(&format!("['{pname}']")))
        .map(|s| s.numel())
        .sum()
}

/// Tab. 3: train with exactly one operator quantized (nvfp4), everything
/// else BF16; sensitivity score = ΔLoss vs BF16 / operator params.
pub fn table3(
    base: &RunConfig,
    ops: &[String],
    steps: usize,
    tail: usize,
) -> Result<Vec<Table3Row>> {
    // BF16 reference
    let mut cfg = base.clone();
    cfg.recipe = "bf16".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    let mut tr = Trainer::new(cfg.clone())?;
    tr.train(steps)?;
    let base_loss = tr.log.tail_mean_loss(tail).unwrap() as f64;
    info!("table3: bf16 baseline loss {base_loss:.6}");

    let mut rows = Vec::new();
    for op in ops {
        let tag = op.replace('.', "_");
        let mut cfg_op = cfg.clone();
        cfg_op.recipe = format!("only_{tag}");
        let mut tr = Trainer::new(cfg_op)
            .with_context(|| format!("loading sensitivity artifact for {op}"))?;
        tr.train(steps)?;
        let loss = tr.log.tail_mean_loss(tail).unwrap() as f64;
        let op_params = op_param_count(tr.train_exe.manifest(), op);
        let delta = loss - base_loss;
        let score = if op_params > 0 {
            delta / op_params as f64 * 1e6
        } else {
            0.0
        };
        info!("table3: {op} loss {loss:.6} Δ {delta:+.6} score {score:+.4}");
        rows.push(Table3Row { op: op.clone(), delta_loss: delta, op_params, score });
    }
    rows.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    Ok(rows)
}

pub fn write_table3(rows: &[Table3Row], path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "operator,delta_loss,op_params,sensitivity_score_x1e6")?;
    for r in rows {
        writeln!(f, "{},{:.6},{},{:.4}", r.op, r.delta_loss, r.op_params, r.score)?;
    }
    Ok(())
}

pub fn print_table3(rows: &[Table3Row]) {
    println!("\nTable 3 — operator quantization sensitivity (normalized)");
    println!(
        "{:<12} {:>12} {:>12} {:>18}",
        "Operator", "ΔLoss", "Params", "Score (Δ/p ×1e6)"
    );
    for r in rows {
        println!(
            "{:<12} {:>12.6} {:>12} {:>18.4}",
            r.op, r.delta_loss, r.op_params, r.score
        );
    }
}
