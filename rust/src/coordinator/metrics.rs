//! Training metrics: per-step records, CSV persistence, small analyses
//! (loss-gap computation for the Tab. 2 report).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// One training step's scalars.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub wall_ms: f64,
}

/// Append-only metric log for one run.
#[derive(Clone, Debug, Default)]
pub struct MetricLog {
    pub records: Vec<StepMetrics>,
}

impl MetricLog {
    pub fn push(&mut self, m: StepMetrics) {
        self.records.push(m);
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `n` records (smoother final-loss estimate).
    pub fn tail_mean_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let k = n.min(self.records.len()).max(1);
        let s: f32 = self.records[self.records.len() - k..]
            .iter()
            .map(|r| r.loss)
            .sum();
        Some(s / k as f32)
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.wall_ms).sum::<f64>() / self.records.len() as f64
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(f, "step,loss,grad_norm,lr,wall_ms")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{:.3}",
                r.step, r.loss, r.grad_norm, r.lr, r.wall_ms
            )?;
        }
        Ok(())
    }
}

/// Relative loss gap vs a baseline, in percent (Tab. 2's "Loss Gap (%)").
pub fn loss_gap_pct(loss: f32, baseline: f32) -> f64 {
    ((loss as f64) - (baseline as f64)) / (baseline as f64) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepMetrics {
        StepMetrics { step, loss, grad_norm: 1.0, lr: 1e-3, wall_ms: 5.0 }
    }

    #[test]
    fn tail_mean() {
        let mut log = MetricLog::default();
        for i in 0..10 {
            log.push(rec(i, i as f32));
        }
        assert_eq!(log.final_loss(), Some(9.0));
        assert_eq!(log.tail_mean_loss(2), Some(8.5));
        assert_eq!(log.tail_mean_loss(100), Some(4.5));
    }

    #[test]
    fn gap_pct() {
        assert!((loss_gap_pct(2.18, 2.168) - 0.5535).abs() < 0.01);
        assert_eq!(loss_gap_pct(2.0, 2.0), 0.0);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("chon_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        let mut log = MetricLog::default();
        log.push(rec(0, 5.0));
        log.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("step,loss"));
        assert!(text.contains("0,5,1,0.001"));
    }
}
