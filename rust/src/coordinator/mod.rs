//! L3 coordinator: training orchestration over the AOT artifacts.
//!
//! The paper's contribution is a training recipe (L1/L2), so L3 is the
//! training-systems substrate the authors got from Flame/FSDP: trainer
//! loop + optimizer state management, data prefetch, the longitudinal
//! outlier monitor, the ablation runners that regenerate Tab. 2/3, the
//! downstream eval suite, and checkpointing.

pub mod ablation;
pub mod evalsuite;
pub mod finetune;
pub mod lifecycle;
pub mod metrics;
pub mod monitor;
pub mod trainer;

pub use lifecycle::{LifecycleEvent, LifecycleKind, LifecycleTracker};
pub use metrics::{loss_gap_pct, MetricLog, StepMetrics};
pub use monitor::{DiagRecord, Monitor};
pub use trainer::Trainer;
