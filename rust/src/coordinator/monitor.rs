//! Longitudinal outlier monitor — the Sec. 3 instrumentation.
//!
//! Stores the diag artifact's metric vector + per-channel magnitude maps
//! at every probe step, derives the paper's longitudinal analyses
//! (hot-channel persistence, kurtosis/FTZ/MSE trajectories) and persists
//! everything as CSV for plotting.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::diagnostics;

/// One diagnostics probe at a training step.
#[derive(Clone, Debug)]
pub struct DiagRecord {
    pub step: usize,
    /// values aligned with `Monitor::names`
    pub values: Vec<f32>,
    /// per-channel max-magnitude maps: (component tag, (layers x channels))
    pub channel_maps: Vec<(String, Vec<Vec<f32>>)>,
}

/// The longitudinal series for one run.
#[derive(Clone, Debug, Default)]
pub struct Monitor {
    pub names: Vec<String>,
    pub records: Vec<DiagRecord>,
}

impl Monitor {
    pub fn new(names: Vec<String>) -> Self {
        Monitor { names, records: Vec::new() }
    }

    /// Rebuild the metric-series view from a run trace (pass the
    /// resume-collapsed `trace::logical_view`): `run_start` carries the
    /// metric names and every `diag` event the full values vector, so
    /// `series`/`series_mean_matching`/`write_csv` work on a crashed
    /// run's trace exactly as on the in-memory monitor. The trace keeps
    /// only top-k channels, not full maps, so reconstructed records
    /// carry empty `channel_maps` (`write_channel_csvs` is a no-op).
    pub fn from_trace_events(events: &[crate::util::json::Json]) -> Monitor {
        use crate::obs::trace;
        let names: Vec<String> = events
            .iter()
            .find(|e| trace::kind(e) == Some("run_start"))
            .and_then(|e| e.get("metric_names"))
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|n| n.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        let mut m = Monitor::new(names);
        for e in events.iter().filter(|e| trace::kind(e) == Some("diag")) {
            let Some(step) = trace::step(e) else { continue };
            let Some(vals) = e.get("values").and_then(|v| v.as_arr()) else {
                continue;
            };
            let values: Vec<f32> = vals
                .iter()
                .filter_map(|v| v.as_f64().map(|n| n as f32))
                .collect();
            if values.len() != m.names.len() {
                continue; // schema drift across an incompatible trace
            }
            m.records.push(DiagRecord {
                step: step as usize,
                values,
                channel_maps: Vec::new(),
            });
        }
        m
    }

    pub fn push(&mut self, rec: DiagRecord) {
        assert_eq!(rec.values.len(), self.names.len(), "diag schema mismatch");
        self.records.push(rec);
    }

    /// Time series of one named metric.
    pub fn series(&self, name: &str) -> Option<Vec<(usize, f32)>> {
        let idx = self.names.iter().position(|n| n == name)?;
        Some(
            self.records
                .iter()
                .map(|r| (r.step, r.values[idx]))
                .collect(),
        )
    }

    /// Mean over all metrics whose name contains `needle` at each step —
    /// e.g. needle=".act.kurt" gives the Fig. 5 activation-kurtosis curve.
    pub fn series_mean_matching(&self, needle: &str) -> Vec<(usize, f32)> {
        let idxs: Vec<usize> = self
            .names
            .iter()
            .enumerate()
            .filter(|(_, n)| n.contains(needle))
            .map(|(i, _)| i)
            .collect();
        if idxs.is_empty() {
            return Vec::new();
        }
        self.records
            .iter()
            .map(|r| {
                let s: f32 = idxs.iter().map(|&i| r.values[i]).sum();
                (r.step, s / idxs.len() as f32)
            })
            .collect()
    }

    /// Hot-channel persistence (Sec. 3.3): Jaccard overlap of the top-k
    /// channel set between consecutive probes, per component map.
    /// Returns (component, Vec<(step, overlap-with-previous)>).
    pub fn hot_channel_persistence(&self, k: usize) -> Vec<(String, Vec<(usize, f64)>)> {
        let mut out = Vec::new();
        if self.records.len() < 2 {
            return out;
        }
        let n_maps = self.records[0].channel_maps.len();
        for mi in 0..n_maps {
            let comp = self.records[0].channel_maps[mi].0.clone();
            let mut series = Vec::new();
            for w in self.records.windows(2) {
                // flatten layers: overlap computed on the concatenated map
                let hot = |r: &DiagRecord| {
                    let flat: Vec<f32> = r.channel_maps[mi]
                        .1
                        .iter()
                        .flatten()
                        .copied()
                        .collect();
                    diagnostics::hot_channels(&flat, k)
                };
                let a = hot(&w[0]);
                let b = hot(&w[1]);
                series.push((w[1].step, diagnostics::channel_overlap(&a, &b)));
            }
            out.push((comp, series));
        }
        out
    }

    /// Write the full metric series as a long-format CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(f, "step,metric,value")?;
        for r in &self.records {
            for (n, v) in self.names.iter().zip(&r.values) {
                writeln!(f, "{},{},{}", r.step, n, v)?;
            }
        }
        Ok(())
    }

    /// Write channel-magnitude maps (one CSV per component).
    pub fn write_channel_csvs(&self, dir: &Path, prefix: &str) -> Result<()> {
        if self.records.is_empty() {
            return Ok(());
        }
        for (mi, (comp, _)) in self.records[0].channel_maps.iter().enumerate() {
            let p = dir.join(format!("{prefix}_channels_{comp}.csv"));
            let mut f = std::io::BufWriter::new(std::fs::File::create(&p)?);
            writeln!(f, "step,layer,channel,max_abs")?;
            for r in &self.records {
                for (li, chans) in r.channel_maps[mi].1.iter().enumerate() {
                    for (ci, &v) in chans.iter().enumerate() {
                        writeln!(f, "{},{},{},{}", r.step, li, ci, v)?;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, v: f32, hot: usize) -> DiagRecord {
        let mut map = vec![0.1f32; 16];
        map[hot] = 10.0;
        DiagRecord {
            step,
            values: vec![v, v * 2.0],
            channel_maps: vec![("gk".into(), vec![map])],
        }
    }

    #[test]
    fn series_lookup() {
        let mut m = Monitor::new(vec!["a.kurt".into(), "b.kurt".into()]);
        m.push(rec(0, 1.0, 3));
        m.push(rec(10, 2.0, 3));
        assert_eq!(m.series("a.kurt").unwrap(), vec![(0, 1.0), (10, 2.0)]);
        let mean = m.series_mean_matching(".kurt");
        assert_eq!(mean, vec![(0, 1.5), (10, 3.0)]);
    }

    #[test]
    fn persistence_detects_fixed_vs_drifting() {
        let mut fixed = Monitor::new(vec!["x".into(), "y".into()]);
        for s in 0..5 {
            fixed.push(rec(s * 10, 1.0, 7)); // same hot channel
        }
        let p = fixed.hot_channel_persistence(1);
        assert!(p[0].1.iter().all(|&(_, j)| j == 1.0));

        let mut drift = Monitor::new(vec!["x".into(), "y".into()]);
        for s in 0..5 {
            drift.push(rec(s * 10, 1.0, s)); // hot channel moves every probe
        }
        let p = drift.hot_channel_persistence(1);
        assert!(p[0].1.iter().all(|&(_, j)| j == 0.0));
    }

    #[test]
    fn from_trace_events_rebuilds_series() {
        use crate::util::json::Json;
        let text = concat!(
            "{\"ev\":\"run_start\",\"step\":0,\"metric_names\":[\"a\",\"b\"]}\n",
            "{\"ev\":\"step\",\"step\":1,\"loss\":3.0}\n",
            "{\"ev\":\"diag\",\"step\":10,\"values\":[1.0,2.0]}\n",
            "{\"ev\":\"diag\",\"step\":20,\"values\":[1.5,2.5]}\n",
            "{\"ev\":\"diag\",\"step\":30,\"values\":[9.0]}\n", // wrong arity: skipped
        );
        let events: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        let m = Monitor::from_trace_events(&events);
        assert_eq!(m.names, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(m.series("a").unwrap(), vec![(10, 1.0), (20, 1.5)]);
        assert_eq!(m.series("b").unwrap(), vec![(10, 2.0), (20, 2.5)]);
    }

    #[test]
    fn csv_output() {
        let dir = std::env::temp_dir().join("chon_monitor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut m = Monitor::new(vec!["a".into(), "b".into()]);
        m.push(rec(0, 1.0, 0));
        m.write_csv(&dir.join("diag.csv")).unwrap();
        m.write_channel_csvs(&dir, "run").unwrap();
        assert!(dir.join("run_channels_gk.csv").exists());
        let text = std::fs::read_to_string(dir.join("diag.csv")).unwrap();
        assert!(text.contains("0,a,1"));
    }
}
