//! The training orchestrator: owns model/optimizer state as host tensors,
//! drives the train/eval/diag executables of the selected backend (native
//! pure-Rust or PJRT), the data prefetcher, the longitudinal monitor and
//! checkpointing. Python never runs here.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::lifecycle::{LifecycleKind, LifecycleTracker};
use crate::coordinator::metrics::{MetricLog, StepMetrics};
use crate::coordinator::monitor::{DiagRecord, Monitor};
use crate::data::batcher::{Batch, Batcher, Prefetcher};
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::tokenizer::Tokenizer;
use crate::info;
use crate::obs::trace::{self, TraceWriter};
use crate::obs::train::{
    PhaseSpans, TrainObs, PH_DATA_WAIT, PH_DIAG,
};
use crate::runtime::ckptdir::{self, CheckpointMeta};
use crate::runtime::{backend_for, Backend, DType, Executable, HostTensor};
use crate::util::json::Json;

/// Top-k size of the online hot-channel tracker and of the per-probe
/// top-k sets stored in the trace (matches the `chon diag` analysis).
pub const HOT_K: usize = crate::coordinator::lifecycle::DEFAULT_K;

/// Model + optimizer state in manifest order.
pub struct TrainState {
    /// parameter tensors, aligned with the "params" input slots
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: usize,
    /// names of the parameter slots (e.g. "params['embed']")
    pub names: Vec<String>,
}

pub struct Trainer {
    pub cfg: RunConfig,
    backend: Box<dyn Backend>,
    pub train_exe: Rc<dyn Executable>,
    /// lazily loaded on first use (XLA compiles are expensive on 1 core)
    diag_exe: Option<Rc<dyn Executable>>,
    eval_exe: Option<Rc<dyn Executable>>,
    diag_tried: bool,
    eval_tried: bool,
    pub state: TrainState,
    pub log: MetricLog,
    pub monitor: Monitor,
    /// the tokenizer the data pipeline runs (persisted into checkpoints)
    pub tokenizer: Tokenizer,
    prefetch: Prefetcher,
    /// batches pulled from the data pipeline so far (training steps plus
    /// diag/eval probes) — checkpointed as the stream position so a
    /// resumed run fast-forwards past already-consumed batches
    batches_consumed: u64,
    /// (batch, seq_len) from the artifact meta
    pub batch: usize,
    pub seq_len: usize,
    pub total_steps: usize,
    /// per-phase span sink, shared with the shard engine (which times
    /// fwd_bwd/allreduce/adam inside `ShardExec::run`) and with any
    /// `TrainObs` scrape registry attached via [`Trainer::set_obs`]
    pub spans: Arc<PhaseSpans>,
    /// live scrape gauges (`--metrics-port`); None = no listener
    obs: Option<Arc<TrainObs>>,
    /// crash-durable JSONL run trace; None until `enable_run_outputs`
    trace: Option<TraceWriter>,
    /// incremental train.csv writer; None until `enable_run_outputs`
    csv: Option<std::io::BufWriter<std::fs::File>>,
    /// online transient-vs-persistent hot-channel classifier
    pub lifecycle: LifecycleTracker,
}

/// Split train-artifact outputs: params, m, v (k each), then scalars.
fn split_state_outputs(
    outputs: Vec<HostTensor>,
    k: usize,
) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>, Vec<f32>)> {
    if outputs.len() < 3 * k + 3 {
        bail!("train outputs {} < 3*{k}+3", outputs.len());
    }
    let mut it = outputs.into_iter();
    let params: Vec<HostTensor> = it.by_ref().take(k).collect();
    let m: Vec<HostTensor> = it.by_ref().take(k).collect();
    let v: Vec<HostTensor> = it.by_ref().take(k).collect();
    let scalars: Vec<f32> = it
        .map(|t| {
            if t.dtype == DType::F32 {
                t.f32_data[0]
            } else {
                t.i32_data[0] as f32
            }
        })
        .collect();
    Ok((params, m, v, scalars))
}

impl Trainer {
    /// Build a trainer from a run config: resolves the backend, loads the
    /// train/init executables, initializes parameters, spins up the data
    /// prefetcher.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        let backend = backend_for(&cfg.backend)?;
        let dir = cfg.artifacts.clone();
        let train_name = format!("train_{}_{}", cfg.model, cfg.recipe);
        // native training always runs through the data-parallel shard
        // engine (default --shards 1): the per-sequence grad + fixed-tree
        // allreduce math is identical for every shard count, so N is a
        // pure scheduling knob (see runtime::native::shard)
        let spans = Arc::new(PhaseSpans::new());
        let train_exe: Rc<dyn Executable> = if backend.name() == "native" {
            Rc::new(
                crate::runtime::native::ShardExec::new(&train_name, cfg.shards)
                    .with_context(|| format!("loading {train_name} (native backend)"))?
                    .with_spans(spans.clone()),
            )
        } else {
            if cfg.shards > 1 {
                bail!(
                    "--shards {} needs the native backend, not {:?}",
                    cfg.shards,
                    cfg.backend
                );
            }
            backend.load(&dir, &train_name).with_context(|| {
                format!("loading {train_name} ({} backend)", backend.name())
            })?
        };
        let man = train_exe.manifest();
        let vocab = man.meta_usize("vocab")?;
        let batch = man.meta_usize("batch")?;
        let seq_len = man.meta_usize("seq_len")?;
        let total_steps = if cfg.steps > 0 {
            cfg.steps
        } else {
            man.meta_usize("total_steps")?
        };
        let names: Vec<String> = man
            .inputs_with_prefix("params")
            .iter()
            .map(|s| s.name.clone())
            .collect();

        // init params
        let init_exe = backend.load(&dir, &format!("init_{}", cfg.model))?;
        let params = init_exe.run(&[HostTensor::scalar_i32(cfg.seed as i32)])?;
        if params.len() != names.len() {
            bail!(
                "init produced {} tensors, train expects {} params",
                params.len(),
                names.len()
            );
        }
        let zeros = |ps: &[HostTensor]| {
            ps.iter()
                .map(|p| HostTensor::zeros(p.dtype, p.shape.clone()))
                .collect()
        };
        let state = TrainState {
            m: zeros(&params),
            v: zeros(&params),
            params,
            step: 0,
            names,
        };

        // data pipeline
        let corpus = Corpus::new(CorpusConfig { seed: cfg.seed, ..CorpusConfig::default() });
        let tok_text = corpus.generate(32 * 1024, u64::MAX);
        let tokenizer = if vocab > 256 {
            Tokenizer::train(&tok_text, vocab)
        } else {
            Tokenizer::byte_level()
        };
        let batcher = Batcher::new(corpus, tokenizer.clone(), batch, seq_len, vocab);
        let prefetch = Prefetcher::spawn(batcher, 4);

        // metric names come from the (cheap) manifest, not the executable
        let metric_names = backend
            .manifest(&dir, &format!("diag_{}_{}", cfg.model, diag_recipe(&cfg.recipe)))
            .map(|m| m.metrics)
            .unwrap_or_default();
        Ok(Trainer {
            cfg,
            backend,
            train_exe,
            diag_exe: None,
            eval_exe: None,
            diag_tried: false,
            eval_tried: false,
            state,
            log: MetricLog::default(),
            monitor: Monitor::new(metric_names),
            tokenizer,
            prefetch,
            batches_consumed: 0,
            batch,
            seq_len,
            total_steps,
            spans,
            obs: None,
            trace: None,
            csv: None,
            lifecycle: LifecycleTracker::new(HOT_K),
        })
    }

    /// The run's output directory, `<out_dir>/<model>_<recipe>/`.
    pub fn run_dir(&self) -> PathBuf {
        self.cfg
            .out_dir
            .join(format!("{}_{}", self.cfg.model, self.cfg.recipe))
    }

    /// Attach the live scrape registry (gauges behind `--metrics-port`).
    /// Pass a `TrainObs` built over [`Trainer::spans`] so phase
    /// histograms and trace spans read the same sink.
    pub fn set_obs(&mut self, obs: Arc<TrainObs>) {
        obs.total_steps.set(self.total_steps as u64);
        self.obs = Some(obs);
    }

    /// Open the per-run telemetry outputs under [`Trainer::run_dir`]:
    /// the incremental `train.csv` (header now, one flushed row per
    /// logging interval — interrupted runs keep partial metrics) and,
    /// unless `--no-trace`, the crash-durable `trace.jsonl`. Call
    /// *after* `restore()` on a resume: the trace is then opened in
    /// append mode behind a validated `resume` marker, and because
    /// resumed training is bit-identical, the logical step series stays
    /// exactly an uninterrupted run's.
    pub fn enable_run_outputs(&mut self) -> Result<PathBuf> {
        let dir = self.run_dir();
        std::fs::create_dir_all(&dir)?;
        let f = std::fs::File::create(dir.join("train.csv"))
            .with_context(|| format!("create {}/train.csv", dir.display()))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "step,loss,grad_norm,lr,wall_ms")?;
        w.flush()?;
        self.csv = Some(w);
        if self.cfg.trace {
            self.open_trace(&dir)?;
        }
        Ok(dir)
    }

    fn open_trace(&mut self, dir: &Path) -> Result<()> {
        let path = dir.join(trace::TRACE_FILE);
        let resuming = self.cfg.resume.is_some() && self.state.step > 0;
        if resuming && path.exists() {
            // step monotonicity: appending a resume at a step the trace
            // never reached would leave a gap indistinguishable from
            // lost data — refuse instead
            let events = trace::read_events(&path)?;
            let last = trace::last_step(&events).unwrap_or(0);
            if self.state.step as u64 > last {
                bail!(
                    "trace {} ends at step {last} but resume starts at \
                     step {} — refusing to append across the gap",
                    path.display(),
                    self.state.step
                );
            }
            self.trace = Some(TraceWriter::append(&path)?);
        } else {
            self.trace = Some(TraceWriter::create(&path)?);
            self.emit_run_start()?;
        }
        if resuming {
            let from = self
                .cfg
                .resume
                .as_ref()
                .map(|p| p.display().to_string())
                .unwrap_or_default();
            self.emit(trace::event(
                "resume",
                vec![
                    ("step", Json::Num(self.state.step as f64)),
                    ("from", Json::Str(from)),
                ],
            ))?;
            if let Some(obs) = &self.obs {
                obs.resumes_total.inc();
            }
        }
        Ok(())
    }

    fn emit_run_start(&self) -> Result<()> {
        let names = self
            .monitor
            .names
            .iter()
            .map(|n| Json::Str(n.clone()))
            .collect();
        self.emit(trace::event(
            "run_start",
            vec![
                ("step", Json::Num(self.state.step as f64)),
                ("model", Json::Str(self.cfg.model.clone())),
                ("recipe", Json::Str(self.cfg.recipe.clone())),
                ("backend", Json::Str(self.cfg.backend.clone())),
                ("seed", Json::Num(self.cfg.seed as f64)),
                ("shards", Json::Num(self.cfg.shards as f64)),
                ("batch", Json::Num(self.batch as f64)),
                ("seq_len", Json::Num(self.seq_len as f64)),
                ("total_steps", Json::Num(self.total_steps as f64)),
                ("metric_names", Json::Arr(names)),
                ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ],
        ))
    }

    /// Emit one trace event if tracing is on (no-op otherwise).
    fn emit(&self, ev: Json) -> Result<()> {
        match &self.trace {
            Some(t) => t.emit(&ev),
            None => Ok(()),
        }
    }

    fn batch_tensors(&self, b: &Batch) -> (HostTensor, HostTensor) {
        (
            HostTensor::i32(vec![b.batch, b.seq_len], b.tokens.clone()),
            HostTensor::i32(vec![b.batch, b.seq_len], b.targets.clone()),
        )
    }

    /// Pull the next batch, advancing the checkpointable stream position.
    fn next_data_batch(&mut self) -> Batch {
        self.batches_consumed += 1;
        self.prefetch.next()
    }

    /// Run one training step; returns its metrics.
    pub fn step(&mut self) -> Result<StepMetrics> {
        let t_data = Instant::now();
        let b = self.next_data_batch();
        self.spans.record_elapsed(PH_DATA_WAIT, t_data.elapsed());
        let (tokens, targets) = self.batch_tensors(&b);
        let t0 = Instant::now();
        let k = self.state.params.len();
        let mut inputs = Vec::with_capacity(3 * k + 4);
        inputs.extend(self.state.params.iter().cloned());
        inputs.extend(self.state.m.iter().cloned());
        inputs.extend(self.state.v.iter().cloned());
        inputs.push(HostTensor::scalar_i32(self.state.step as i32));
        inputs.push(tokens);
        inputs.push(targets);
        inputs.push(HostTensor::scalar_i32(self.cfg.seed as i32));
        let outputs = self.train_exe.run(&inputs)?;
        let (params, m, v, scalars) = split_state_outputs(outputs, k)?;
        self.state.params = params;
        self.state.m = m;
        self.state.v = v;
        self.state.step += 1;
        let met = StepMetrics {
            step: self.state.step,
            loss: scalars[0],
            grad_norm: scalars[1],
            lr: scalars[2],
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        self.log.push(met);
        self.after_step(&met)?;
        Ok(met)
    }

    /// Telemetry fan-out after a completed step: the incremental CSV
    /// row, the live gauges, and the trace's step + span events. Pure
    /// observation — training state is already advanced.
    fn after_step(&mut self, met: &StepMetrics) -> Result<()> {
        let tokens = (self.batch * self.seq_len) as u64;
        let tps = if met.wall_ms > 0.0 {
            tokens as f64 / (met.wall_ms / 1e3)
        } else {
            0.0
        };
        if let Some(w) = &mut self.csv {
            writeln!(
                w,
                "{},{},{},{},{:.3}",
                met.step, met.loss, met.grad_norm, met.lr, met.wall_ms
            )?;
            // flush per logging interval (every step when --log-every 0)
            if met.step % self.cfg.log_every.max(1) == 0 {
                w.flush()?;
            }
        }
        if let Some(obs) = &self.obs {
            obs.record_step(
                met.step, met.loss, met.grad_norm, met.lr, tokens, tps,
            );
        }
        if self.trace.is_some() {
            self.emit(trace::event(
                "step",
                vec![
                    ("step", Json::Num(met.step as f64)),
                    ("loss", Json::Num(met.loss as f64)),
                    ("grad_norm", Json::Num(met.grad_norm as f64)),
                    ("lr", Json::Num(met.lr as f64)),
                    ("wall_ms", Json::Num(met.wall_ms)),
                    ("tokens", Json::Num(tokens as f64)),
                    ("tokens_per_s", Json::Num(tps)),
                ],
            ))?;
            let us = crate::obs::train::PHASES
                .iter()
                .take(PH_DIAG) // per-step phases; diag spans ride the diag event
                .enumerate()
                .map(|(i, p)| {
                    (p.to_string(), Json::Num(self.spans.last(i) as f64))
                })
                .collect();
            self.emit(trace::event(
                "span",
                vec![
                    ("step", Json::Num(met.step as f64)),
                    ("us", Json::Obj(us)),
                ],
            ))?;
        }
        Ok(())
    }

    /// Lazily load the diag executable (expensive on PJRT; only when probing).
    fn ensure_diag(&mut self) -> Option<&dyn Executable> {
        if !self.diag_tried {
            self.diag_tried = true;
            self.diag_exe = self
                .backend
                .load(
                    &self.cfg.artifacts,
                    &format!("diag_{}_{}", self.cfg.model, diag_recipe(&self.cfg.recipe)),
                )
                .ok();
        }
        self.diag_exe.as_deref()
    }

    /// Lazily load the eval executable.
    pub fn ensure_eval(&mut self) -> Option<&dyn Executable> {
        if !self.eval_tried {
            self.eval_tried = true;
            self.eval_exe = self
                .backend
                .load(
                    &self.cfg.artifacts,
                    &format!("eval_{}_{}", self.cfg.model, eval_recipe(&self.cfg.recipe)),
                )
                .ok();
        }
        self.eval_exe.as_deref()
    }

    /// Run the diag artifact on a fresh batch and record it: into the
    /// monitor, through the online lifecycle tracker (birth/death
    /// classification), and out to the trace and the live gauges.
    pub fn diagnose(&mut self) -> Result<()> {
        if self.ensure_diag().is_none() {
            return Ok(());
        }
        let t0 = Instant::now();
        let diag = self.diag_exe.as_ref().unwrap().clone();
        let b = self.next_data_batch();
        let (tokens, _) = self.batch_tensors(&b);
        let mut inputs = self.state.params.clone();
        inputs.push(tokens);
        inputs.push(HostTensor::scalar_i32(self.state.step as i32));
        let outputs = diag.run(&inputs)?;
        // output 0: metric vector; 1..: channel maps (layers x channels)
        let values = outputs[0].f32_data.clone();
        let map_names: Vec<&str> = match outputs.len() {
            4 => vec!["attn_o", "mlp_up", "attn_gk"],
            3 => vec!["attn_o", "mlp_up"],
            n => bail!("unexpected diag output count {n}"),
        };
        let mut channel_maps = Vec::new();
        for (t, name) in outputs[1..].iter().zip(map_names) {
            let (layers, chans) = (t.shape[0], t.shape[1]);
            let rows = (0..layers)
                .map(|l| t.f32_data[l * chans..(l + 1) * chans].to_vec())
                .collect();
            channel_maps.push((name.to_string(), rows));
        }

        // online lifecycle pass over the layer-flattened maps (the same
        // flattening hot_channel_persistence uses)
        let step = self.state.step;
        let mut topk: Vec<(String, Json)> = Vec::new();
        let mut transitions = Vec::new();
        for (name, rows) in &channel_maps {
            let flat: Vec<f32> = rows.iter().flatten().copied().collect();
            let ob = self.lifecycle.observe(step, name, &flat);
            if let Some(obs) = &self.obs {
                let c = obs.comp(name);
                let (pers, trans) = self.lifecycle.counts(name);
                c.persistent.set(pers as u64);
                c.transient.set(trans as u64);
                if let Some(j) = ob.overlap {
                    c.persistence.set(j);
                }
                for e in &ob.events {
                    match e.kind {
                        LifecycleKind::Birth => c.births.inc(),
                        LifecycleKind::Death => c.deaths.inc(),
                    }
                }
            }
            topk.push((
                name.clone(),
                Json::Arr(
                    ob.top
                        .iter()
                        .map(|&(c, mag)| {
                            Json::Arr(vec![
                                Json::Num(c as f64),
                                Json::Num(mag as f64),
                            ])
                        })
                        .collect(),
                ),
            ));
            transitions.extend(ob.events);
        }
        self.spans.record_elapsed(PH_DIAG, t0.elapsed());

        if self.trace.is_some() {
            self.emit(trace::event(
                "diag",
                vec![
                    ("step", Json::Num(step as f64)),
                    (
                        "us",
                        Json::Num(t0.elapsed().as_micros() as f64),
                    ),
                    (
                        "values",
                        Json::Arr(
                            values
                                .iter()
                                .map(|&v| Json::Num(v as f64))
                                .collect(),
                        ),
                    ),
                    ("topk", Json::Obj(topk)),
                ],
            ))?;
            for e in &transitions {
                let kind = match e.kind {
                    LifecycleKind::Birth => "hot_birth",
                    LifecycleKind::Death => "hot_death",
                };
                self.emit(trace::event(
                    kind,
                    vec![
                        ("step", Json::Num(e.step as f64)),
                        ("comp", Json::Str(e.comp.clone())),
                        ("channel", Json::Num(e.channel as f64)),
                        ("ewma", Json::Num(e.ewma as f64)),
                    ],
                ))?;
            }
        }
        self.monitor.push(DiagRecord { step, values, channel_maps });
        Ok(())
    }

    /// Evaluate held-out loss/accuracy on `n_batches` fresh batches.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<(f32, f32)> {
        if self.ensure_eval().is_none() {
            bail!("no eval artifact for {}/{}", self.cfg.model, self.cfg.recipe);
        }
        let eval = self.eval_exe.as_ref().unwrap().clone();
        let mut loss = 0.0f32;
        let mut acc = 0.0f32;
        for _ in 0..n_batches {
            let b = self.next_data_batch();
            let (tokens, targets) = self.batch_tensors(&b);
            let mut inputs = self.state.params.clone();
            inputs.push(tokens);
            inputs.push(targets);
            let out = eval.run(&inputs)?;
            loss += out[0].f32_data[0];
            acc += out[1].f32_data[0];
        }
        Ok((loss / n_batches as f32, acc / n_batches as f32))
    }

    /// Main training loop with periodic diag/eval/logging.
    pub fn train(&mut self, steps: usize) -> Result<()> {
        for _ in 0..steps {
            let met = self.step()?;
            if self.cfg.log_every > 0 && met.step % self.cfg.log_every == 0 {
                info!(
                    "step {:4}  loss {:.4}  gnorm {:.3}  lr {:.2e}  {:.0} ms",
                    met.step, met.loss, met.grad_norm, met.lr, met.wall_ms
                );
            }
            if self.cfg.diag_every > 0 && met.step % self.cfg.diag_every == 0 {
                self.diagnose()?;
            }
            if self.cfg.eval_every > 0
                && met.step % self.cfg.eval_every == 0
                && self.ensure_eval().is_some()
            {
                let (l, a) = self.evaluate(2)?;
                info!("eval @ {}: loss {:.4} acc {:.3}", met.step, l, a);
            }
            if let Some(dir) = &self.cfg.checkpoint_dir {
                if met.step % 100 == 0 {
                    self.save_checkpoint_to(dir)?;
                }
            }
        }
        Ok(())
    }

    /// The (name, shape) layout restores must match.
    fn param_layout(&self) -> Vec<(String, Vec<usize>)> {
        self.state
            .names
            .iter()
            .cloned()
            .zip(self.state.params.iter().map(|t| t.shape.clone()))
            .collect()
    }

    /// Persist the full run state to a checkpoint *directory*
    /// `<dir>/<model>_<recipe>_<step>/` — params, optimizer state,
    /// tokenizer vocab and run metadata (see `runtime::ckptdir`). Every
    /// save stamps `meta.toml` with a monotonically increasing
    /// `generation` (scanned from what is already under `dir`), which is
    /// what lets a live `chon serve` registry hot-reload republished
    /// checkpoints without a restart.
    pub fn save_checkpoint_to(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!(
            "{}_{}_{:05}",
            self.cfg.model, self.cfg.recipe, self.state.step
        ));
        let meta = CheckpointMeta {
            format_version: ckptdir::FORMAT_VERSION,
            model: self.cfg.model.clone(),
            recipe: self.cfg.recipe.clone(),
            seed: self.cfg.seed,
            step: self.state.step,
            vocab: self.tokenizer.vocab,
            data_batches: self.batches_consumed,
            generation: ckptdir::next_generation(dir),
        };
        let tensors: Vec<(String, HostTensor)> = self
            .state
            .names
            .iter()
            .cloned()
            .zip(self.state.params.iter().cloned())
            .collect();
        ckptdir::save_dir(
            &path,
            &meta,
            &tensors,
            Some((self.state.m.as_slice(), self.state.v.as_slice(), self.state.step)),
            &self.tokenizer,
        )?;
        self.emit(trace::event(
            "ckpt",
            vec![
                ("step", Json::Num(self.state.step as f64)),
                ("path", Json::Str(path.display().to_string())),
            ],
        ))?;
        Ok(path)
    }

    /// Restore *params only* from a checkpoint dir (or a legacy single
    /// `.ckpt` file). Optimizer state and step are untouched — use
    /// `restore` for a full resume. Tensor names and shapes must match
    /// this trainer's model; the checkpoint's recipe may differ (the
    /// finetune flow trains a bf16 checkpoint under quantized recipes).
    pub fn load_params(&mut self, path: &Path) -> Result<()> {
        let tensors = if path.is_dir() {
            ckptdir::load_dir(&ckptdir::resolve(path)?, &self.param_layout())?.params
        } else {
            crate::runtime::load_checkpoint(path)?
        };
        if tensors.len() != self.state.params.len() {
            bail!(
                "checkpoint has {} tensors, expected {}",
                tensors.len(),
                self.state.params.len()
            );
        }
        for ((name, t), want) in tensors.iter().zip(&self.state.names) {
            if name != want {
                bail!("checkpoint tensor {name} != expected {want}");
            }
            let _ = t;
        }
        self.state.params = tensors.into_iter().map(|(_, t)| t).collect();
        Ok(())
    }

    /// Full resume from a checkpoint dir: params + Adam m/v + step. The
    /// checkpoint must have been written for this (model, recipe) pair —
    /// silently resetting the optimizer was the old behavior and is now an
    /// explicit error instead.
    ///
    /// The data-stream position (`meta.data_batches`) is restored by
    /// fast-forwarding the deterministic pipeline past the batches the
    /// original run already consumed, so a resumed run's per-step losses
    /// are bit-identical to an uninterrupted run's
    /// (`tests/serve_invariants.rs`). Pre-v2 checkpoints carry no
    /// position (0): legacy behavior, the stream restarts from its head.
    pub fn restore(&mut self, path: &Path) -> Result<()> {
        let dir = ckptdir::resolve(path)?;
        let loaded = ckptdir::load_dir(&dir, &self.param_layout())?;
        if loaded.meta.model != self.cfg.model {
            bail!(
                "checkpoint {} was trained on model {:?}, trainer runs {:?}",
                dir.display(),
                loaded.meta.model,
                self.cfg.model
            );
        }
        if loaded.meta.recipe != self.cfg.recipe {
            bail!(
                "checkpoint {} was trained with recipe {:?}, trainer runs {:?} \
                 (use load_params to transplant params across recipes)",
                dir.display(),
                loaded.meta.recipe,
                self.cfg.recipe
            );
        }
        let Some(optim) = loaded.optim else {
            bail!(
                "checkpoint {} has no optimizer state (inference-only copy?)",
                dir.display()
            );
        };
        self.state.params = loaded.params.into_iter().map(|(_, t)| t).collect();
        self.state.m = optim.m;
        self.state.v = optim.v;
        self.state.step = optim.step;
        // fast-forward the (deterministic) data stream to the position
        // the checkpoint was written at; batches are discarded in order,
        // so the next pull sees exactly what the original run would have
        while self.batches_consumed < loaded.meta.data_batches {
            let _ = self.next_data_batch();
        }
        Ok(())
    }

    /// Write run outputs (metrics CSV, diag CSVs) to the out dir and
    /// mark the trace complete. With the incremental writer active the
    /// CSV already holds every row — a final flush, not a rewrite (a
    /// rewrite under the still-open handle would interleave its
    /// drop-flush into the fresh file).
    pub fn write_outputs(&mut self) -> Result<PathBuf> {
        let dir = self.run_dir();
        std::fs::create_dir_all(&dir)?;
        match self.csv.take() {
            Some(mut w) => w.flush()?,
            None => self.log.write_csv(&dir.join("train.csv"))?,
        }
        if !self.monitor.records.is_empty() {
            self.monitor.write_csv(&dir.join("diag.csv"))?;
            self.monitor.write_channel_csvs(&dir, "diag")?;
        }
        let mut fields = vec![("step", Json::Num(self.state.step as f64))];
        if let Some(loss) = self.log.final_loss() {
            fields.push(("loss", Json::Num(loss as f64)));
        }
        self.emit(trace::event("run_end", fields))?;
        Ok(dir)
    }
}

fn diag_recipe(recipe: &str) -> &str {
    // diag artifacts exist for chon + bf16; others reuse chon's probes
    if recipe == "bf16" {
        "bf16"
    } else {
        "chon"
    }
}

fn eval_recipe(recipe: &str) -> &str {
    match recipe {
        "bf16" | "fp8" | "nvfp4" | "chon" => recipe,
        _ => "chon",
    }
}
