//! Online hot-channel lifecycle tracking: the paper's Sec. 3.3 finding
//! — outliers start as transient spikes and harden into persistent hot
//! channels — turned into a live, queryable signal. Each diag probe
//! feeds the flattened per-component channel map in; the tracker keeps
//! an EWMA magnitude and a consecutive-probes-in-top-k streak per
//! channel, classifies channels transient vs persistent, and emits
//! birth/death events the trainer writes into the run trace and counts
//! on `/metrics`.
//!
//! Channel indices are the same flattened `layer * chans + chan` space
//! `Monitor::hot_channel_persistence` uses, and top-k membership comes
//! from the same `diagnostics::hot_channels` selection, so the online
//! classification is consistent with the offline Jaccard series.

use crate::diagnostics;

/// Top-k size used by the trainer's tracker (matches the `diag`
/// command's persistence analysis).
pub const DEFAULT_K: usize = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifecycleKind {
    /// channel promoted to persistent (streak reached `persist_after`)
    Birth,
    /// persistent channel missed `death_after` consecutive probes
    Death,
}

#[derive(Clone, Debug)]
pub struct LifecycleEvent {
    pub step: usize,
    pub comp: String,
    pub channel: usize,
    pub kind: LifecycleKind,
    /// EWMA |magnitude| at classification time
    pub ewma: f32,
}

/// What one probe of one component yields.
pub struct Observation {
    /// top-k `(flat channel, magnitude)` of this probe, descending —
    /// exactly `diagnostics::hot_channels(flat, k)`
    pub top: Vec<(usize, f32)>,
    pub events: Vec<LifecycleEvent>,
    /// Jaccard overlap with the previous probe's top-k (None on the
    /// component's first probe)
    pub overlap: Option<f64>,
}

struct CompState {
    name: String,
    ewma: Vec<f32>,
    /// consecutive probes in the top-k
    streak: Vec<u32>,
    /// consecutive probes out of the top-k (persistent channels only)
    miss: Vec<u32>,
    persistent: Vec<bool>,
    prev_top: Option<Vec<(usize, f32)>>,
}

impl CompState {
    fn grow(&mut self, n: usize) {
        if self.ewma.len() < n {
            self.ewma.resize(n, 0.0);
            self.streak.resize(n, 0);
            self.miss.resize(n, 0);
            self.persistent.resize(n, false);
        }
    }
}

pub struct LifecycleTracker {
    pub k: usize,
    /// consecutive probes in the top-k before a channel is persistent
    pub persist_after: u32,
    /// consecutive misses before a persistent channel dies
    pub death_after: u32,
    /// EWMA decay: `ewma' = decay·ewma + (1−decay)·|mag|`
    pub decay: f32,
    comps: Vec<CompState>,
}

impl LifecycleTracker {
    pub fn new(k: usize) -> LifecycleTracker {
        LifecycleTracker {
            k,
            persist_after: 3,
            death_after: 3,
            decay: 0.8,
            comps: Vec::new(),
        }
    }

    fn comp_mut(&mut self, name: &str) -> &mut CompState {
        if let Some(i) = self.comps.iter().position(|c| c.name == name) {
            return &mut self.comps[i];
        }
        self.comps.push(CompState {
            name: name.to_string(),
            ewma: Vec::new(),
            streak: Vec::new(),
            miss: Vec::new(),
            persistent: Vec::new(),
            prev_top: None,
        });
        self.comps.last_mut().unwrap()
    }

    fn comp(&self, name: &str) -> Option<&CompState> {
        self.comps.iter().find(|c| c.name == name)
    }

    /// Feed one probe of one component (`flat` is the layer-flattened
    /// |magnitude| map). Returns the probe's top-k, any birth/death
    /// transitions, and the consecutive-probe Jaccard overlap.
    pub fn observe(
        &mut self,
        step: usize,
        comp: &str,
        flat: &[f32],
    ) -> Observation {
        let top = diagnostics::hot_channels(flat, self.k);
        let (persist_after, death_after, decay) =
            (self.persist_after, self.death_after, self.decay);
        let st = self.comp_mut(comp);
        st.grow(flat.len());
        let overlap = st
            .prev_top
            .as_ref()
            .map(|p| diagnostics::channel_overlap(p, &top));
        let mut in_top = vec![false; st.ewma.len()];
        for &(c, _) in &top {
            if c < in_top.len() {
                in_top[c] = true;
            }
        }
        let mut events = Vec::new();
        for c in 0..st.ewma.len() {
            if in_top[c] {
                st.ewma[c] =
                    decay * st.ewma[c] + (1.0 - decay) * flat[c].abs();
                st.miss[c] = 0;
                st.streak[c] += 1;
                if !st.persistent[c] && st.streak[c] >= persist_after {
                    st.persistent[c] = true;
                    events.push(LifecycleEvent {
                        step,
                        comp: comp.to_string(),
                        channel: c,
                        kind: LifecycleKind::Birth,
                        ewma: st.ewma[c],
                    });
                }
            } else {
                st.ewma[c] *= decay;
                st.streak[c] = 0;
                if st.persistent[c] {
                    st.miss[c] += 1;
                    if st.miss[c] >= death_after {
                        st.persistent[c] = false;
                        st.miss[c] = 0;
                        events.push(LifecycleEvent {
                            step,
                            comp: comp.to_string(),
                            channel: c,
                            kind: LifecycleKind::Death,
                            ewma: st.ewma[c],
                        });
                    }
                }
            }
        }
        st.prev_top = Some(top.clone());
        Observation { top, events, overlap }
    }

    /// `(persistent, transient)` channel counts for a component —
    /// transient = in the latest top-k but not classified persistent.
    pub fn counts(&self, comp: &str) -> (usize, usize) {
        let Some(st) = self.comp(comp) else { return (0, 0) };
        let persistent = st.persistent.iter().filter(|p| **p).count();
        let transient = st
            .prev_top
            .as_ref()
            .map(|top| {
                top.iter()
                    .filter(|(c, _)| !st.persistent.get(*c).copied().unwrap_or(false))
                    .count()
            })
            .unwrap_or(0);
        (persistent, transient)
    }

    /// Currently-persistent channel indices for a component.
    pub fn persistent_channels(&self, comp: &str) -> Vec<usize> {
        self.comp(comp)
            .map(|st| {
                st.persistent
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| **p)
                    .map(|(c, _)| c)
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A channel that is hot on every probe must become persistent
    /// (one birth, no deaths), consistent with channel_overlap == 1.0
    /// between consecutive probes.
    #[test]
    fn fixed_hot_channel_becomes_persistent() {
        let mut t = LifecycleTracker::new(2);
        let mut births = 0;
        let mut deaths = 0;
        for step in 0..10 {
            // channel 3 always dominant, channel 0 runner-up
            let flat = vec![1.0, 0.1, 0.1, 9.0, 0.1, 0.1];
            let ob = t.observe(step, "attn_o", &flat);
            assert_eq!(ob.top[0].0, 3);
            if step > 0 {
                assert_eq!(ob.overlap, Some(1.0), "identical top-k every probe");
            }
            for e in &ob.events {
                match e.kind {
                    LifecycleKind::Birth => births += 1,
                    LifecycleKind::Death => deaths += 1,
                }
            }
        }
        assert_eq!(births, 2, "both always-hot channels born exactly once");
        assert_eq!(deaths, 0);
        let p = t.persistent_channels("attn_o");
        assert!(p.contains(&3) && p.contains(&0), "{p:?}");
        let (pers, trans) = t.counts("attn_o");
        assert_eq!((pers, trans), (2, 0));
    }

    /// A spike that drifts to a different channel every probe never
    /// builds a streak: no births, everything stays transient —
    /// consistent with channel_overlap == 0.0 between probes.
    #[test]
    fn drifting_spike_stays_transient() {
        let mut t = LifecycleTracker::new(1);
        for step in 0..8 {
            let mut flat = vec![0.0f32; 8];
            flat[step % 8] = 5.0; // a different channel every probe
            let ob = t.observe(step, "mlp_up", &flat);
            assert!(ob.events.is_empty(), "no lifecycle transitions");
            if step > 0 {
                assert_eq!(ob.overlap, Some(0.0), "disjoint consecutive top-k");
            }
        }
        let (pers, trans) = t.counts("mlp_up");
        assert_eq!(pers, 0);
        assert_eq!(trans, 1, "the latest spike is transient");
    }

    /// Persistent → cold → death after `death_after` misses; EWMA
    /// decays while cold.
    #[test]
    fn cold_persistent_channel_dies() {
        let mut t = LifecycleTracker::new(1);
        for step in 0..4 {
            t.observe(step, "c", &[7.0, 0.0]);
        }
        assert_eq!(t.persistent_channels("c"), vec![0]);
        let mut death_step = None;
        for step in 4..10 {
            let ob = t.observe(step, "c", &[0.0, 7.0]); // heat moved away
            if let Some(e) = ob.events.first() {
                assert_eq!(e.kind, LifecycleKind::Death);
                assert_eq!(e.channel, 0);
                death_step = Some(step);
                break;
            }
        }
        assert_eq!(death_step, Some(6), "death after 3 consecutive misses");
        assert!(t.persistent_channels("c").is_empty());
    }

    /// Streaks must be *consecutive*: an interruption resets progress
    /// toward persistence.
    #[test]
    fn interrupted_streak_resets() {
        let mut t = LifecycleTracker::new(1);
        let hot = [9.0f32, 0.0];
        let cold = [0.0f32, 9.0];
        for (step, flat) in
            [hot, hot, cold, hot, hot, cold].iter().enumerate()
        {
            let ob = t.observe(step, "c", flat);
            assert!(
                ob.events.is_empty(),
                "2-streaks never reach persist_after=3"
            );
        }
        assert!(t.persistent_channels("c").is_empty());
    }

    #[test]
    fn components_are_independent() {
        let mut t = LifecycleTracker::new(1);
        for step in 0..5 {
            t.observe(step, "a", &[9.0, 0.0]);
            t.observe(step, "b", &[0.0, 9.0]);
        }
        assert_eq!(t.persistent_channels("a"), vec![0]);
        assert_eq!(t.persistent_channels("b"), vec![1]);
        assert_eq!(t.counts("nope"), (0, 0));
    }
}
