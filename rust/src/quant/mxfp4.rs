//! MXFP4 baseline (OCP MX spec): 32-wide blocks, power-of-two (E8M0)
//! shared scales, no global scale. The comparison format for the NVFP4
//! recipe discussion (§2 Related Work, Quartet/AWS baselines).

use crate::quant::e2m1;

pub const BLOCK: usize = 32;

/// floor(log2 a) via f32 bits (a > 0, normal).
#[inline]
fn floor_log2(a: f32) -> i32 {
    (((a.to_bits() >> 23) & 0xFF) as i32) - 127
}

/// Fake-quantize with OCP MX semantics: shared exp = floor(log2 amax) - 2.
pub fn fake_quant(x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len() % BLOCK, 0);
    let mut out = Vec::with_capacity(x.len());
    for blk in x.chunks(BLOCK) {
        let amax_b = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax_b == 0.0 {
            out.extend(std::iter::repeat(0.0).take(BLOCK));
            continue;
        }
        let s_dec = (2.0f32).powi(floor_log2(amax_b) - 2);
        for &v in blk {
            out.push(e2m1::rtn(v / s_dec) * s_dec);
        }
    }
    out
}

pub fn quant_mse(x: &[f32]) -> f64 {
    let d = fake_quant(x);
    x.iter()
        .zip(&d)
        .map(|(&a, &b)| {
            let e = (a - b) as f64;
            e * e
        })
        .sum::<f64>()
        / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::nvfp4;
    use crate::util::prng::Rng;

    #[test]
    fn error_bounded() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() * 2.0).collect();
        let d = fake_quant(&x);
        for (blk, dblk) in x.chunks(32).zip(d.chunks(32)) {
            let amax_b = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for (a, b) in blk.iter().zip(dblk) {
                assert!((a - b).abs() <= amax_b / 2.0 + 1e-7);
            }
        }
    }

    #[test]
    fn nvfp4_beats_mxfp4_on_gaussian() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..8192).map(|_| rng.normal() * 1.7).collect();
        assert!(nvfp4::quant_mse(&x) < quant_mse(&x));
    }

    #[test]
    fn zero_block() {
        let x = vec![0.0f32; 32];
        assert!(fake_quant(&x).iter().all(|&v| v == 0.0));
    }
}
