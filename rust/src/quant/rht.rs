//! Randomized Hadamard Transform (backward/Wgrad path, App. C.3).
//!
//! In-place iterative FWHT butterflies; same pairing as ref.py's reshape
//! formulation, so cross-language fixtures agree. `rht`/`rht_inv` are the
//! orthonormal (1/sqrt n) randomized pair.

use crate::util::ndarray::Mat;
use crate::util::prng::Rng;

/// In-place unnormalized FWHT over a power-of-2-length slice.
/// fwht(fwht(x)) == n * x.
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT size {n} not a power of 2");
    let mut h = 1;
    while h < n {
        for group in (0..n).step_by(2 * h) {
            for j in group..group + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Random ±1 signs derived from an Rng.
pub fn random_signs(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.sign()).collect()
}

/// Orthonormal randomized Hadamard over the rows of a matrix (last dim).
pub fn rht(x: &Mat, signs: &[f32]) -> Mat {
    assert_eq!(x.cols, signs.len());
    let scale = 1.0 / (x.cols as f32).sqrt();
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        for (v, &s) in row.iter_mut().zip(signs) {
            *v *= s;
        }
        fwht_inplace(row);
        for v in row.iter_mut() {
            *v *= scale;
        }
    }
    out
}

/// Inverse of `rht`.
pub fn rht_inv(y: &Mat, signs: &[f32]) -> Mat {
    assert_eq!(y.cols, signs.len());
    let scale = 1.0 / (y.cols as f32).sqrt();
    let mut out = y.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        fwht_inplace(row);
        for (v, &s) in row.iter_mut().zip(signs) {
            *v *= scale * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn involution() {
        let mut x = vec![1.0f32, 2.0, -3.0, 0.5, 7.0, -1.0, 0.0, 4.0];
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 8.0 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn hadamard_2() {
        let mut x = vec![3.0f32, 1.0];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![4.0, 2.0]);
    }

    #[test]
    fn rht_roundtrip() {
        let x = rand_mat(8, 64, 1);
        let mut rng = Rng::new(2);
        let s = random_signs(64, &mut rng);
        let y = rht(&x, &s);
        let back = rht_inv(&y, &s);
        for (a, b) in x.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn energy_preserved() {
        let x = rand_mat(4, 128, 3);
        let mut rng = Rng::new(4);
        let s = random_signs(128, &mut rng);
        let y = rht(&x, &s);
        assert!((x.frob_sq() - y.frob_sq()).abs() / x.frob_sq() < 1e-5);
    }

    #[test]
    fn diffuses_spike() {
        let mut x = Mat::zeros(1, 256);
        *x.at_mut(0, 100) = 64.0;
        let mut rng = Rng::new(5);
        let s = random_signs(256, &mut rng);
        let y = rht(&x, &s);
        let max = y.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!((max - 64.0 / 16.0).abs() < 1e-4, "spike -> uniform ±4");
    }

    #[test]
    fn wgrad_identity_before_quant() {
        // (H X)^T (H dY) == X^T dY (orthogonality of the transform)
        use crate::util::ndarray::matmul;
        let x = rand_mat(64, 8, 6); // contraction dim = rows = 64
        let dy = rand_mat(64, 5, 7);
        let mut rng = Rng::new(8);
        let s = random_signs(64, &mut rng);
        let xr = rht(&x.transpose(), &s).transpose();
        let dyr = rht(&dy.transpose(), &s).transpose();
        let want = matmul(&x.transpose(), &dy);
        let got = matmul(&xr.transpose(), &dyr);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
