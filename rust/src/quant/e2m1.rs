//! FP4 E2M1 codec: 16 code points, RTN-even / floor / stochastic rounding.
//!
//! Code layout (4 bits): `s eem` — sign, 2 exponent bits (bias 1),
//! 1 mantissa bit. Values: ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}.
//!
//! The rounding functions mirror python/compile/kernels/ref.py exactly
//! (piecewise uniform sub-lattices with round-half-even), so Rust-side
//! diagnostics agree with the AOT'd model numerics.

/// Largest representable magnitude.
pub const E2M1_MAX: f32 = 6.0;

/// The 8 non-negative code point values, indexed by the low 3 bits.
pub const E2M1_VALUES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Round half to even on the integer lattice.
#[inline]
fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) & 1 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Round-to-nearest-even onto the E2M1 lattice (|v| clamped to 6).
#[inline]
pub fn rtn(v: f32) -> f32 {
    let a = v.abs().min(E2M1_MAX);
    let s = if v.is_sign_negative() { -1.0 } else { 1.0 };
    let r = if a < 2.0 {
        round_half_even(a * 2.0) * 0.5
    } else if a < 4.0 {
        round_half_even(a)
    } else {
        round_half_even(a * 0.5) * 2.0
    };
    s * r
}

/// Round toward zero onto the lattice.
#[inline]
pub fn floor(v: f32) -> f32 {
    let a = v.abs().min(E2M1_MAX);
    let s = if v.is_sign_negative() { -1.0 } else { 1.0 };
    let r = if a < 2.0 {
        (a * 2.0).floor() * 0.5
    } else if a < 4.0 {
        a.floor()
    } else {
        (a * 0.5).floor() * 2.0
    };
    s * r
}

/// Lattice spacing above magnitude `a`.
#[inline]
pub fn spacing(a: f32) -> f32 {
    if a < 2.0 {
        0.5
    } else if a < 4.0 {
        1.0
    } else {
        2.0
    }
}

/// Stochastic rounding with uniform `u` in [0, 1).
#[inline]
pub fn sr(v: f32, u: f32) -> f32 {
    let a = v.abs().min(E2M1_MAX);
    let s = if v.is_sign_negative() { -1.0 } else { 1.0 };
    let lo = if a < 2.0 {
        (a * 2.0).floor() * 0.5
    } else if a < 4.0 {
        a.floor()
    } else {
        (a * 0.5).floor() * 2.0
    };
    let hi = (lo + spacing(lo)).min(E2M1_MAX);
    let frac = if hi > lo { (a - lo) / (hi - lo) } else { 0.0 };
    s * if u < frac { hi } else { lo }
}

/// Encode a lattice value (must be exact) into a 4-bit code.
pub fn encode(v: f32) -> u8 {
    let sign = if v.is_sign_negative() && v != 0.0 { 8u8 } else { 0 };
    let a = v.abs();
    let mag = E2M1_VALUES
        .iter()
        .position(|&x| x == a)
        .unwrap_or_else(|| panic!("not an E2M1 value: {v}"));
    sign | mag as u8
}

/// Decode a 4-bit code to its f32 value.
#[inline]
pub fn decode(code: u8) -> f32 {
    let v = E2M1_VALUES[(code & 7) as usize];
    if code & 8 != 0 {
        -v
    } else {
        v
    }
}

/// Quantize (RTN) and encode in one step.
#[inline]
pub fn encode_rtn(v: f32) -> u8 {
    encode(rtn(v))
}

/// Pack 4-bit codes two per byte (low nibble first).
pub fn pack(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = pair[0] & 0xF;
        let hi = if pair.len() > 1 { pair[1] & 0xF } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack two-per-byte nibbles back into `n` codes.
pub fn unpack(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for &b in packed {
        out.push(b & 0xF);
        if out.len() < n {
            out.push(b >> 4);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_ties_to_even() {
        // (input, expected) — identical table to the Python tests.
        let cases = [
            (0.25, 0.0),
            (0.75, 1.0),
            (1.25, 1.0),
            (1.75, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (5.0, 4.0),
            (0.26, 0.5),
            (5.01, 6.0),
            (100.0, 6.0),
            (-2.5, -2.0),
            (-100.0, -6.0),
        ];
        for (x, want) in cases {
            assert_eq!(rtn(x), want, "rtn({x})");
        }
    }

    #[test]
    fn all_codes_roundtrip() {
        for code in 0u8..16 {
            let v = decode(code);
            if v == 0.0 && code == 8 {
                continue; // -0 normalizes to +0 code
            }
            assert_eq!(decode(encode(v)), v);
            assert_eq!(rtn(v), v, "code points are fixed points");
        }
    }

    #[test]
    fn floor_toward_zero() {
        assert_eq!(floor(0.49), 0.0);
        assert_eq!(floor(1.99), 1.5);
        assert_eq!(floor(3.99), 3.0);
        assert_eq!(floor(5.99), 4.0);
        assert_eq!(floor(-1.99), -1.5);
    }

    #[test]
    fn sr_unbiased() {
        let mut state = 0x1234_5678_u64;
        let mut next_u = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 40) as f32) / (1u64 << 24) as f32
        };
        for &v in &[0.3f32, 1.2, 2.7, 4.5, -0.7, -3.3] {
            let n = 200_000;
            let mean: f64 = (0..n).map(|_| sr(v, next_u()) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - v as f64).abs() < 0.02,
                "sr bias at {v}: mean {mean}"
            );
        }
    }

    #[test]
    fn sr_lands_on_neighbours() {
        for i in 0..1000 {
            let v = -6.0 + 12.0 * (i as f32) / 1000.0;
            let lo = floor(v);
            let hi_mag = (lo.abs() + spacing(lo.abs())).min(E2M1_MAX);
            for u in [0.0, 0.3, 0.7, 0.999] {
                let q = sr(v, u);
                assert!(
                    q == lo || q.abs() == hi_mag,
                    "sr({v}, {u}) = {q}, lo={lo}"
                );
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..33).map(|i| (i % 16) as u8).collect();
        let packed = pack(&codes);
        assert_eq!(packed.len(), 17);
        assert_eq!(unpack(&packed, 33), codes);
    }

    #[test]
    fn rtn_is_nearest() {
        let codes: Vec<f32> = (0u8..16).map(decode).collect();
        for i in 0..2000 {
            let v = -7.0 + 14.0 * (i as f32) / 2000.0;
            let q = rtn(v);
            let vc = v.clamp(-6.0, 6.0);
            let best = codes
                .iter()
                .map(|&c| (c - vc).abs())
                .fold(f32::INFINITY, f32::min);
            assert!((q - vc).abs() <= best + 1e-6, "rtn({v})={q}");
        }
    }
}
