//! Recover-block mechanism (App. C.3 recipe item ⑤): an NVFP4-specific
//! rehydration step for weight regions exhibiting *transient* outliers.
//!
//! A per-block EMA of the block amax tracks each block's steady-state
//! range. When a block's instantaneous amax spikes above
//! `threshold × EMA`, quantizing it would either clip the spike (2D
//! shared scales) or flush the block's small values (inflated local
//! scale); the recover mechanism instead "rehydrates" the block — keeps
//! it in high precision for that step — and lets the EMA absorb the new
//! range over subsequent steps. Persistent growth therefore re-enters the
//! quantized path automatically, matching the paper's transient-vs-
//! persistent outlier taxonomy (Sec. 3.3).

use crate::quant::nvfp4::{self, Rounding, BLOCK};

/// Streaming per-block range tracker + selective rehydration.
#[derive(Clone, Debug)]
pub struct RecoverBlocks {
    /// EMA of per-block amax (None until first observation)
    ema: Vec<f32>,
    initialized: bool,
    /// EMA smoothing factor
    pub alpha: f32,
    /// spike threshold: rehydrate when amax > threshold * ema
    pub threshold: f32,
    /// blocks rehydrated on the last step (diagnostics)
    pub last_recovered: usize,
    /// total rehydration events
    pub total_recovered: usize,
    steps: usize,
}

impl RecoverBlocks {
    pub fn new(n_blocks: usize, alpha: f32, threshold: f32) -> Self {
        RecoverBlocks {
            ema: vec![0.0; n_blocks],
            initialized: false,
            alpha,
            threshold,
            last_recovered: 0,
            total_recovered: 0,
            steps: 0,
        }
    }

    /// Number of tracked blocks.
    pub fn n_blocks(&self) -> usize {
        self.ema.len()
    }

    /// Quantize-dequantize `x`, rehydrating transient-spike blocks.
    ///
    /// Returns the fake-quantized tensor; spiking blocks pass through in
    /// full precision this step. Updates the EMA with the observed amax.
    pub fn fake_quant_with_recovery(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ema.len() * BLOCK, "block count mismatch");
        let mut out = nvfp4::fake_quant(x, Rounding::Rtn, None);
        self.last_recovered = 0;
        self.steps += 1;
        for (b, blk) in x.chunks(BLOCK).enumerate() {
            let amax = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if self.initialized {
                let ema = self.ema[b];
                if ema > 0.0 && amax > self.threshold * ema {
                    // transient spike: rehydrate the block this step
                    out[b * BLOCK..(b + 1) * BLOCK].copy_from_slice(blk);
                    self.last_recovered += 1;
                    self.total_recovered += 1;
                }
            }
            self.ema[b] = if self.initialized {
                (1.0 - self.alpha) * self.ema[b] + self.alpha * amax
            } else {
                amax
            };
        }
        self.initialized = true;
        out
    }

    /// Fraction of blocks rehydrated on the last call.
    pub fn recovery_rate(&self) -> f64 {
        self.last_recovered as f64 / self.ema.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn base_tensor(n_blocks: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n_blocks * BLOCK).map(|_| rng.normal()).collect()
    }

    #[test]
    fn steady_state_never_recovers() {
        let mut rb = RecoverBlocks::new(8, 0.1, 4.0);
        for step in 0..20 {
            let x = base_tensor(8, step);
            rb.fake_quant_with_recovery(&x);
        }
        assert_eq!(rb.total_recovered, 0, "gaussian steady state is quiet");
    }

    #[test]
    fn transient_spike_is_rehydrated_exactly() {
        let mut rb = RecoverBlocks::new(8, 0.1, 4.0);
        // warm up the EMA
        for step in 0..5 {
            rb.fake_quant_with_recovery(&base_tensor(8, step));
        }
        // inject a 100x spike into block 3
        let mut x = base_tensor(8, 99);
        let spike_pos = 3 * BLOCK + 7;
        x[spike_pos] = 100.0;
        let out = rb.fake_quant_with_recovery(&x);
        assert_eq!(rb.last_recovered, 1);
        // the whole block passed through unquantized
        assert_eq!(&out[3 * BLOCK..4 * BLOCK], &x[3 * BLOCK..4 * BLOCK]);
        // neighbours still quantized (value changed by quantization)
        let prev_block = &out[2 * BLOCK..3 * BLOCK];
        assert_ne!(prev_block, &x[2 * BLOCK..3 * BLOCK]);
    }

    #[test]
    fn persistent_growth_reenters_quantized_path() {
        let mut rb = RecoverBlocks::new(4, 0.5, 3.0);
        for step in 0..5 {
            rb.fake_quant_with_recovery(&base_tensor(4, step));
        }
        // block 0 becomes persistently hot: after the EMA adapts,
        // recovery stops firing.
        let mut fired = Vec::new();
        for step in 0..10 {
            let mut x = base_tensor(4, 100 + step);
            for v in x[..BLOCK].iter_mut() {
                *v *= 50.0;
            }
            rb.fake_quant_with_recovery(&x);
            fired.push(rb.last_recovered);
        }
        assert!(fired[0] >= 1, "first spike recovered");
        assert_eq!(*fired.last().unwrap(), 0, "EMA absorbed the new range");
    }

    #[test]
    fn recovery_reduces_error_under_spikes() {
        let mut rb = RecoverBlocks::new(8, 0.1, 4.0);
        for step in 0..5 {
            rb.fake_quant_with_recovery(&base_tensor(8, step));
        }
        let mut x = base_tensor(8, 7);
        x[5] = 500.0; // block-0 spike flushes its neighbours without recovery
        let with = rb.fake_quant_with_recovery(&x);
        let without = nvfp4::fake_quant(&x, Rounding::Rtn, None);
        let mse = |d: &[f32]| {
            x.iter()
                .zip(d)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(mse(&with) < mse(&without) / 10.0);
    }

    #[test]
    #[should_panic(expected = "block count mismatch")]
    fn wrong_size_rejected() {
        let mut rb = RecoverBlocks::new(4, 0.1, 4.0);
        rb.fake_quant_with_recovery(&[0.0; 16]);
    }
}
