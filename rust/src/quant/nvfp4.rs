//! NVFP4 two-level microscaling quantizer (App. C.4), Rust substrate.
//!
//! Packed representation: 4-bit E2M1 codes (2/byte), one E4M3 (u8) decode
//! scale per 1x16 block, one global f32 decode scale — exactly the tensor
//! layout a Blackwell tensor-core GEMM consumes (Eq. 44). `fake_quant`
//! shortcuts quantize→dequantize for diagnostics and parity tests against
//! python/compile/kernels/ref.py.

use crate::quant::{e2m1, e4m3};
use crate::util::ndarray::{Mat, KC, NR};
use crate::util::prng::Rng;

pub const BLOCK: usize = 16;

/// Rounding mode for the element quantizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Round-to-nearest-even (forward path).
    Rtn,
    /// Stochastic rounding (backward path).
    Sr,
}

/// A quantized tensor in storage format.
#[derive(Clone, Debug)]
pub struct Quantized {
    pub n: usize,
    /// packed 4-bit codes, two per byte
    pub codes: Vec<u8>,
    /// one E4M3-encoded decode scale per block
    pub scales: Vec<u8>,
    /// global decode scale (f32, Def. C.1)
    pub s_dec: f32,
}

impl Quantized {
    /// Storage bytes (the memory-footprint model for EXPERIMENTS.md).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + 4
    }
}

/// Global encode scale (Def. C.1): map amax onto 6*448.
#[inline]
pub fn global_enc_scale(amax: f32) -> f32 {
    if amax > 0.0 {
        (e2m1::E2M1_MAX * e4m3::E4M3_MAX) / amax
    } else {
        1.0
    }
}

fn amax(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Quantize a flat slice with 1x16 block scaling. n % 16 == 0.
pub fn quantize(x: &[f32], rounding: Rounding, rng: Option<&mut Rng>) -> Quantized {
    assert_eq!(x.len() % BLOCK, 0, "len {} % 16 != 0", x.len());
    let s_enc = global_enc_scale(amax(x));
    let s_dec = 1.0 / s_enc;
    let nblocks = x.len() / BLOCK;
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(nblocks);
    let mut local_rng;
    let rng = match rng {
        Some(r) => r,
        None => {
            local_rng = Rng::new(0);
            &mut local_rng
        }
    };
    for b in 0..nblocks {
        let blk = &x[b * BLOCK..(b + 1) * BLOCK];
        let amax_b = amax(blk);
        let s_dec_b = amax_b / e2m1::E2M1_MAX;
        let s_e4m3_code = e4m3::encode(s_dec_b * s_enc);
        let s_e4m3 = e4m3::decode(s_e4m3_code);
        scales.push(s_e4m3_code);
        let denom = s_e4m3 * s_dec;
        let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        for &v in blk {
            let scaled = v * s_enc_b;
            let q = match rounding {
                Rounding::Rtn => e2m1::rtn(scaled),
                Rounding::Sr => e2m1::sr(scaled, rng.uniform()),
            };
            codes.push(e2m1::encode(q));
        }
    }
    Quantized { n: x.len(), codes: e2m1::pack(&codes), scales, s_dec }
}

/// Dequantize back to f32.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let codes = e2m1::unpack(&q.codes, q.n);
    let mut out = Vec::with_capacity(q.n);
    for (b, &sc) in q.scales.iter().enumerate() {
        let s = e4m3::decode(sc) * q.s_dec;
        for i in 0..BLOCK {
            out.push(e2m1::decode(codes[b * BLOCK + i]) * s);
        }
    }
    out
}

/// One KC-row contraction block of a [`PackedQuantMat`] (mirrors
/// `ndarray::PackedBlock`).
#[derive(Clone, Debug)]
pub struct PackedQuantBlock {
    /// first k row covered by this block
    pub(crate) k0: usize,
    /// rows in this block (== KC except possibly the last)
    pub(crate) kc: usize,
    /// byte offset of this block's codes (panel-major)
    pub(crate) codes_off: usize,
    /// byte offset of this block's scale codes (panel-major)
    pub(crate) scales_off: usize,
}

/// A frozen k×n weight resident as packed NVFP4: e2m1 nibble codes +
/// per-(16-k-run, column) e4m3 scales + one global f32 decode scale,
/// laid out in the same NR/KC B-panel order as `ndarray::pack_b` so the
/// quantized microkernel decodes panels in-register.
///
/// Layout per KC block, per panel p (NR output columns):
/// - codes: `kc` rows × NR/2 bytes; column j sits in nibble j%2 of byte
///   j/2, low nibble first — `codes_off + p*kc*(NR/2) + kk*(NR/2) + j/2`
/// - scales: one e4m3 code per (16-k-run g, column j) —
///   `scales_off + p*ngroups*NR + g*NR + j`, `ngroups = ceil(kc/16)`
///
/// Blocks run down k (the contraction dimension, what a tensor-core GEMM
/// consumes) rather than along rows like [`fake_quant_mat`]; the last
/// k-run of a block may cover fewer than 16 rows. The ragged right edge
/// (j ≥ n) packs code 0 under an amax-0 scale, decoding to exact 0.0.
#[derive(Clone, Debug)]
pub struct PackedQuantMat {
    pub(crate) k: usize,
    pub(crate) n: usize,
    pub(crate) npanels: usize,
    pub(crate) blocks: Vec<PackedQuantBlock>,
    pub(crate) codes: Vec<u8>,
    pub(crate) scales: Vec<u8>,
    /// global decode scale (Def. C.1)
    pub(crate) s_dec: f32,
}

impl PackedQuantMat {
    /// Quantize + pack a k×n weight (RTN — the frozen-weights path).
    /// Per-block scale math is step-for-step the one in [`quantize`],
    /// with blocks running down k instead of along the flat slice.
    pub fn pack(w: &Mat) -> Self {
        let (k, n) = (w.rows, w.cols);
        let npanels = n.div_ceil(NR);
        let s_enc = global_enc_scale(amax(&w.data));
        let s_dec = 1.0 / s_enc;
        let mut blocks = Vec::with_capacity(k.div_ceil(KC));
        let mut codes = Vec::with_capacity(k.div_ceil(2) * npanels * NR);
        let mut scales = Vec::with_capacity(k.div_ceil(BLOCK) * npanels * NR);
        let mut senc = vec![0.0f32; KC.div_ceil(BLOCK) * NR];
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            let ngroups = kc.div_ceil(BLOCK);
            blocks.push(PackedQuantBlock {
                k0,
                kc,
                codes_off: codes.len(),
                scales_off: scales.len(),
            });
            for p in 0..npanels {
                let c0 = p * NR;
                // scales first: the code goes to storage, its exact
                // decoded value drives element encoding (as in quantize)
                for g in 0..ngroups {
                    let r1 = kc.min((g + 1) * BLOCK);
                    for j in 0..NR {
                        let col = c0 + j;
                        let mut amax_b = 0.0f32;
                        if col < n {
                            for kk in g * BLOCK..r1 {
                                amax_b = amax_b.max(w.at(k0 + kk, col).abs());
                            }
                        }
                        let s_e4m3_code = e4m3::encode(amax_b / e2m1::E2M1_MAX * s_enc);
                        let s_e4m3 = e4m3::decode(s_e4m3_code);
                        scales.push(s_e4m3_code);
                        let denom = s_e4m3 * s_dec;
                        senc[g * NR + j] = if denom > 0.0 { 1.0 / denom } else { 0.0 };
                    }
                }
                for kk in 0..kc {
                    let g = kk / BLOCK;
                    for j2 in 0..NR / 2 {
                        let q = |j: usize| -> u8 {
                            let col = c0 + j;
                            if col < n {
                                e2m1::encode(e2m1::rtn(w.at(k0 + kk, col) * senc[g * NR + j]))
                            } else {
                                0
                            }
                        };
                        codes.push(q(2 * j2) | (q(2 * j2 + 1) << 4));
                    }
                }
            }
        }
        PackedQuantMat { k, n, npanels, blocks, codes, scales, s_dec }
    }

    pub fn rows(&self) -> usize {
        self.k
    }

    pub fn cols(&self) -> usize {
        self.n
    }

    /// Resident bytes of the packed operand (codes + scales + global
    /// scale) — what `chon_model_weight_bytes{mode="packed"}` reports.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + 4
    }

    /// Decode back to a dense k×n f32 matrix. This is the kernel's
    /// reference: `matmul_quant_packed(a, q)` is bitwise
    /// `matmul(a, &q.dequantize_mat())`. The scale product is computed
    /// e4m3-decode-first (`s = e4m3 * s_dec`, then `e2m1 * s`) — the
    /// same association order as the kernel's sv precompute; f32
    /// multiplication is not associative, so the order is load-bearing.
    pub fn dequantize_mat(&self) -> Mat {
        let mut out = Mat::zeros(self.k, self.n);
        for blk in &self.blocks {
            let ngroups = blk.kc.div_ceil(BLOCK);
            for p in 0..self.npanels {
                let c0 = p * NR;
                let ncols = (self.n - c0).min(NR);
                for kk in 0..blk.kc {
                    let row = blk.codes_off + p * blk.kc * (NR / 2) + kk * (NR / 2);
                    let srow = blk.scales_off + p * ngroups * NR + (kk / BLOCK) * NR;
                    for j in 0..ncols {
                        let byte = self.codes[row + j / 2];
                        let code = if j % 2 == 0 { byte & 0xF } else { byte >> 4 };
                        let s = e4m3::decode(self.scales[srow + j]) * self.s_dec;
                        *out.at_mut(blk.k0 + kk, c0 + j) = e2m1::decode(code) * s;
                    }
                }
            }
        }
        out
    }
}

/// quantize→dequantize in one pass (no packing), matching ref.py exactly.
pub fn fake_quant(x: &[f32], rounding: Rounding, rng: Option<&mut Rng>) -> Vec<f32> {
    assert_eq!(x.len() % BLOCK, 0);
    let s_enc = global_enc_scale(amax(x));
    let s_dec = 1.0 / s_enc;
    let mut out = Vec::with_capacity(x.len());
    let mut local_rng;
    let rng = match rng {
        Some(r) => r,
        None => {
            local_rng = Rng::new(0);
            &mut local_rng
        }
    };
    for blk in x.chunks(BLOCK) {
        let amax_b = amax(blk);
        let s_e4m3 = e4m3::rtn(amax_b / e2m1::E2M1_MAX * s_enc);
        let denom = s_e4m3 * s_dec;
        let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        for &v in blk {
            let q = match rounding {
                Rounding::Rtn => e2m1::rtn(v * s_enc_b),
                Rounding::Sr => e2m1::sr(v * s_enc_b, rng.uniform()),
            };
            out.push(q * s_e4m3 * s_dec);
        }
    }
    out
}

/// Fake-quantize a matrix with 1D (per-row 1x16) block scaling.
pub fn fake_quant_mat(x: &Mat) -> Mat {
    Mat::from_vec(x.rows, x.cols, fake_quant(&x.data, Rounding::Rtn, None))
}

/// Fake-quantize with 2D (tile x 16) block scaling along rows
/// (ref.nvfp4_quant_dequant_2d semantics, weights path).
pub fn fake_quant_mat_2d(x: &Mat, tile: usize) -> Mat {
    assert_eq!(x.cols % BLOCK, 0);
    let s_enc = global_enc_scale(amax(&x.data));
    let s_dec = 1.0 / s_enc;
    let mut out = Mat::zeros(x.rows, x.cols);
    let nblocks = x.cols / BLOCK;
    for band0 in (0..x.rows).step_by(tile) {
        let band_end = (band0 + tile).min(x.rows);
        for b in 0..nblocks {
            // amax over the (tile x 16) brick
            let mut amax_b = 0.0f32;
            for r in band0..band_end {
                for c in b * BLOCK..(b + 1) * BLOCK {
                    amax_b = amax_b.max(x.at(r, c).abs());
                }
            }
            let s_e4m3 = e4m3::rtn(amax_b / e2m1::E2M1_MAX * s_enc);
            let denom = s_e4m3 * s_dec;
            let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
            for r in band0..band_end {
                for c in b * BLOCK..(b + 1) * BLOCK {
                    let q = e2m1::rtn(x.at(r, c) * s_enc_b);
                    *out.at_mut(r, c) = q * s_e4m3 * s_dec;
                }
            }
        }
    }
    out
}

/// Flush-to-zero ratio: fraction of nonzero inputs quantizing to exact 0.
pub fn ftz_ratio(x: &[f32]) -> f64 {
    let deq = fake_quant(x, Rounding::Rtn, None);
    let mut nz = 0usize;
    let mut flushed = 0usize;
    for (&v, &d) in x.iter().zip(&deq) {
        if v != 0.0 {
            nz += 1;
            if d == 0.0 {
                flushed += 1;
            }
        }
    }
    if nz == 0 {
        0.0
    } else {
        flushed as f64 / nz as f64
    }
}

/// Mean squared quantization error.
pub fn quant_mse(x: &[f32]) -> f64 {
    let deq = fake_quant(x, Rounding::Rtn, None);
    x.iter()
        .zip(&deq)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn pack_roundtrip_matches_fake_quant() {
        let x = randn(256, 1, 2.0);
        let q = quantize(&x, Rounding::Rtn, None);
        let deq = dequantize(&q);
        let fq = fake_quant(&x, Rounding::Rtn, None);
        for (a, b) in deq.iter().zip(&fq) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_4bit_plus_scales() {
        let x = randn(1024, 2, 1.0);
        let q = quantize(&x, Rounding::Rtn, None);
        // 512 code bytes + 64 scale bytes + 4 global
        assert_eq!(q.storage_bytes(), 512 + 64 + 4);
    }

    #[test]
    fn error_bounded_by_block_amax() {
        let x = randn(512, 3, 3.0);
        let fq = fake_quant(&x, Rounding::Rtn, None);
        for (blk, dblk) in x.chunks(16).zip(fq.chunks(16)) {
            let amax_b = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = amax_b / 6.0 * (1.0 + 0.125) + 1e-7;
            for (a, b) in blk.iter().zip(dblk) {
                assert!((a - b).abs() <= bound, "err {} bound {}", (a - b).abs(), bound);
            }
        }
    }

    #[test]
    fn zero_tensor() {
        let x = vec![0.0f32; 64];
        assert!(fake_quant(&x, Rounding::Rtn, None).iter().all(|&v| v == 0.0));
        assert_eq!(ftz_ratio(&x), 0.0);
    }

    #[test]
    fn outlier_flushes_block_neighbours() {
        let mut x = vec![0.01f32; 64];
        x[5] = 1000.0;
        let d = fake_quant(&x, Rounding::Rtn, None);
        assert!(d[0] == 0.0 && d[1] == 0.0, "small block-0 values flushed");
        assert!((d[5] - 1000.0).abs() / 1000.0 < 0.07);
        // other blocks keep their values
        assert!((d[20] - 0.01).abs() / 0.01 < 0.25);
        assert!(ftz_ratio(&x) > 0.0);
    }

    #[test]
    fn sr_unbiased_pipeline() {
        let x = randn(64, 4, 1.0);
        let mut rng = Rng::new(5);
        let n = 2000;
        let mut acc = vec![0.0f64; 64];
        for _ in 0..n {
            let d = fake_quant(&x, Rounding::Sr, Some(&mut rng));
            for (a, &v) in acc.iter_mut().zip(&d) {
                *a += v as f64;
            }
        }
        for (i, (&a, &v)) in acc.iter().zip(&x).enumerate() {
            let mean = a / n as f64;
            let blk = &x[(i / 16) * 16..(i / 16 + 1) * 16];
            let amax_b = blk.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
            assert!(
                (mean - v as f64).abs() < (amax_b / 6.0) as f64 + 0.02,
                "bias at {i}: {mean} vs {v}"
            );
        }
    }

    #[test]
    fn fake_quant_2d_tile1_equals_1d() {
        let x = Mat::from_vec(8, 32, randn(256, 6, 1.0));
        let a = fake_quant_mat(&x);
        let b = fake_quant_mat_2d(&x, 1);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn fake_quant_2d_not_finer_than_1d() {
        let x = Mat::from_vec(64, 64, randn(4096, 7, 2.0));
        let e1 = x.mse(&fake_quant_mat(&x));
        let e2 = x.mse(&fake_quant_mat_2d(&x, 16));
        assert!(e2 >= e1 * 0.999, "2D {e2} vs 1D {e1}");
    }

    #[test]
    fn packed_mat_single_column_matches_quantize() {
        // With n == 1 the packed codec's k-direction 16-runs coincide
        // with quantize's flat 16-blocks and the global amax covers the
        // same slice, so the decode must match bitwise.
        let col = randn(64, 11, 2.0);
        let w = Mat::from_vec(64, 1, col.clone());
        let q = PackedQuantMat::pack(&w);
        assert_eq!((q.rows(), q.cols()), (64, 1));
        let deq = q.dequantize_mat();
        let want = dequantize(&quantize(&col, Rounding::Rtn, None));
        for (r, &v) in want.iter().enumerate() {
            assert_eq!(deq.at(r, 0), v, "row {r}");
        }
    }

    #[test]
    fn packed_mat_ragged_error_bounded() {
        // ragged in every direction: k not a multiple of 16 or KC,
        // n not a multiple of NR, degenerate 1x1
        for &(k, n) in &[(1usize, 1usize), (15, 17), (257, 16), (300, 33), (512, 48)] {
            let w = Mat::from_vec(k, n, randn(k * n, (k * 31 + n) as u64, 1.5));
            let q = PackedQuantMat::pack(&w);
            let deq = q.dequantize_mat();
            assert_eq!((deq.rows, deq.cols), (k, n));
            // per-(16-k-run, column) bound, k-runs restarting at KC edges
            for c in 0..n {
                for k0 in (0..k).step_by(KC) {
                    let kc = KC.min(k - k0);
                    for g0 in (0..kc).step_by(BLOCK) {
                        let g1 = kc.min(g0 + BLOCK);
                        let amax_b = (g0..g1)
                            .fold(0.0f32, |m, kk| m.max(w.at(k0 + kk, c).abs()));
                        let bound = amax_b / 6.0 * 1.125 + 1e-6;
                        for kk in g0..g1 {
                            let err = (w.at(k0 + kk, c) - deq.at(k0 + kk, c)).abs();
                            assert!(
                                err <= bound,
                                "({k},{n}) r={} c={c}: err {err} bound {bound}",
                                k0 + kk
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn packed_mat_storage_is_4bit_plus_scales() {
        let w = Mat::from_vec(512, 32, randn(512 * 32, 12, 1.0));
        let q = PackedQuantMat::pack(&w);
        // 2 nibbles/byte + one scale byte per 16 weights + global scale
        assert_eq!(q.storage_bytes(), 512 * 32 / 2 + (512 / 16) * 32 + 4);
        // ~4.5 bits/weight vs 32 — the resident-memory win
        assert!(q.storage_bytes() * 7 < 512 * 32 * 4);
    }

    #[test]
    fn packed_mat_zero_matrix_decodes_to_zero() {
        let w = Mat::zeros(40, 20);
        let q = PackedQuantMat::pack(&w);
        assert_eq!(q.s_dec, 1.0);
        assert!(q.dequantize_mat().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn packed_mat_zeroed_rows_decode_to_exact_zero() {
        // the hot-channel split zeroes rows before packing; those rows
        // must come back as exact 0.0 so the side-GEMM owns them alone
        let mut w = Mat::from_vec(96, 24, randn(96 * 24, 13, 2.0));
        for c in 0..24 {
            *w.at_mut(17, c) = 0.0;
            *w.at_mut(64, c) = 0.0;
        }
        let deq = PackedQuantMat::pack(&w).dequantize_mat();
        for c in 0..24 {
            assert_eq!(deq.at(17, c), 0.0);
            assert_eq!(deq.at(64, c), 0.0);
        }
    }

    #[test]
    fn mse_scales_quadratically() {
        let x = randn(1024, 8, 1.0);
        let x10: Vec<f32> = x.iter().map(|&v| v * 10.0).collect();
        let m1 = quant_mse(&x);
        let m2 = quant_mse(&x10);
        assert!((m2 / m1 - 100.0).abs() < 7.0, "ratio {}", m2 / m1);
    }
}
