//! NVFP4 two-level microscaling quantizer (App. C.4), Rust substrate.
//!
//! Packed representation: 4-bit E2M1 codes (2/byte), one E4M3 (u8) decode
//! scale per 1x16 block, one global f32 decode scale — exactly the tensor
//! layout a Blackwell tensor-core GEMM consumes (Eq. 44). `fake_quant`
//! shortcuts quantize→dequantize for diagnostics and parity tests against
//! python/compile/kernels/ref.py.

use crate::quant::{e2m1, e4m3};
use crate::util::ndarray::Mat;
use crate::util::prng::Rng;

pub const BLOCK: usize = 16;

/// Rounding mode for the element quantizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Round-to-nearest-even (forward path).
    Rtn,
    /// Stochastic rounding (backward path).
    Sr,
}

/// A quantized tensor in storage format.
#[derive(Clone, Debug)]
pub struct Quantized {
    pub n: usize,
    /// packed 4-bit codes, two per byte
    pub codes: Vec<u8>,
    /// one E4M3-encoded decode scale per block
    pub scales: Vec<u8>,
    /// global decode scale (f32, Def. C.1)
    pub s_dec: f32,
}

impl Quantized {
    /// Storage bytes (the memory-footprint model for EXPERIMENTS.md).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() + 4
    }
}

/// Global encode scale (Def. C.1): map amax onto 6*448.
#[inline]
pub fn global_enc_scale(amax: f32) -> f32 {
    if amax > 0.0 {
        (e2m1::E2M1_MAX * e4m3::E4M3_MAX) / amax
    } else {
        1.0
    }
}

fn amax(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Quantize a flat slice with 1x16 block scaling. n % 16 == 0.
pub fn quantize(x: &[f32], rounding: Rounding, rng: Option<&mut Rng>) -> Quantized {
    assert_eq!(x.len() % BLOCK, 0, "len {} % 16 != 0", x.len());
    let s_enc = global_enc_scale(amax(x));
    let s_dec = 1.0 / s_enc;
    let nblocks = x.len() / BLOCK;
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(nblocks);
    let mut local_rng;
    let rng = match rng {
        Some(r) => r,
        None => {
            local_rng = Rng::new(0);
            &mut local_rng
        }
    };
    for b in 0..nblocks {
        let blk = &x[b * BLOCK..(b + 1) * BLOCK];
        let amax_b = amax(blk);
        let s_dec_b = amax_b / e2m1::E2M1_MAX;
        let s_e4m3_code = e4m3::encode(s_dec_b * s_enc);
        let s_e4m3 = e4m3::decode(s_e4m3_code);
        scales.push(s_e4m3_code);
        let denom = s_e4m3 * s_dec;
        let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        for &v in blk {
            let scaled = v * s_enc_b;
            let q = match rounding {
                Rounding::Rtn => e2m1::rtn(scaled),
                Rounding::Sr => e2m1::sr(scaled, rng.uniform()),
            };
            codes.push(e2m1::encode(q));
        }
    }
    Quantized { n: x.len(), codes: e2m1::pack(&codes), scales, s_dec }
}

/// Dequantize back to f32.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let codes = e2m1::unpack(&q.codes, q.n);
    let mut out = Vec::with_capacity(q.n);
    for (b, &sc) in q.scales.iter().enumerate() {
        let s = e4m3::decode(sc) * q.s_dec;
        for i in 0..BLOCK {
            out.push(e2m1::decode(codes[b * BLOCK + i]) * s);
        }
    }
    out
}

/// quantize→dequantize in one pass (no packing), matching ref.py exactly.
pub fn fake_quant(x: &[f32], rounding: Rounding, rng: Option<&mut Rng>) -> Vec<f32> {
    assert_eq!(x.len() % BLOCK, 0);
    let s_enc = global_enc_scale(amax(x));
    let s_dec = 1.0 / s_enc;
    let mut out = Vec::with_capacity(x.len());
    let mut local_rng;
    let rng = match rng {
        Some(r) => r,
        None => {
            local_rng = Rng::new(0);
            &mut local_rng
        }
    };
    for blk in x.chunks(BLOCK) {
        let amax_b = amax(blk);
        let s_e4m3 = e4m3::rtn(amax_b / e2m1::E2M1_MAX * s_enc);
        let denom = s_e4m3 * s_dec;
        let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        for &v in blk {
            let q = match rounding {
                Rounding::Rtn => e2m1::rtn(v * s_enc_b),
                Rounding::Sr => e2m1::sr(v * s_enc_b, rng.uniform()),
            };
            out.push(q * s_e4m3 * s_dec);
        }
    }
    out
}

/// Fake-quantize a matrix with 1D (per-row 1x16) block scaling.
pub fn fake_quant_mat(x: &Mat) -> Mat {
    Mat::from_vec(x.rows, x.cols, fake_quant(&x.data, Rounding::Rtn, None))
}

/// Fake-quantize with 2D (tile x 16) block scaling along rows
/// (ref.nvfp4_quant_dequant_2d semantics, weights path).
pub fn fake_quant_mat_2d(x: &Mat, tile: usize) -> Mat {
    assert_eq!(x.cols % BLOCK, 0);
    let s_enc = global_enc_scale(amax(&x.data));
    let s_dec = 1.0 / s_enc;
    let mut out = Mat::zeros(x.rows, x.cols);
    let nblocks = x.cols / BLOCK;
    for band0 in (0..x.rows).step_by(tile) {
        let band_end = (band0 + tile).min(x.rows);
        for b in 0..nblocks {
            // amax over the (tile x 16) brick
            let mut amax_b = 0.0f32;
            for r in band0..band_end {
                for c in b * BLOCK..(b + 1) * BLOCK {
                    amax_b = amax_b.max(x.at(r, c).abs());
                }
            }
            let s_e4m3 = e4m3::rtn(amax_b / e2m1::E2M1_MAX * s_enc);
            let denom = s_e4m3 * s_dec;
            let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
            for r in band0..band_end {
                for c in b * BLOCK..(b + 1) * BLOCK {
                    let q = e2m1::rtn(x.at(r, c) * s_enc_b);
                    *out.at_mut(r, c) = q * s_e4m3 * s_dec;
                }
            }
        }
    }
    out
}

/// Flush-to-zero ratio: fraction of nonzero inputs quantizing to exact 0.
pub fn ftz_ratio(x: &[f32]) -> f64 {
    let deq = fake_quant(x, Rounding::Rtn, None);
    let mut nz = 0usize;
    let mut flushed = 0usize;
    for (&v, &d) in x.iter().zip(&deq) {
        if v != 0.0 {
            nz += 1;
            if d == 0.0 {
                flushed += 1;
            }
        }
    }
    if nz == 0 {
        0.0
    } else {
        flushed as f64 / nz as f64
    }
}

/// Mean squared quantization error.
pub fn quant_mse(x: &[f32]) -> f64 {
    let deq = fake_quant(x, Rounding::Rtn, None);
    x.iter()
        .zip(&deq)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn pack_roundtrip_matches_fake_quant() {
        let x = randn(256, 1, 2.0);
        let q = quantize(&x, Rounding::Rtn, None);
        let deq = dequantize(&q);
        let fq = fake_quant(&x, Rounding::Rtn, None);
        for (a, b) in deq.iter().zip(&fq) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_4bit_plus_scales() {
        let x = randn(1024, 2, 1.0);
        let q = quantize(&x, Rounding::Rtn, None);
        // 512 code bytes + 64 scale bytes + 4 global
        assert_eq!(q.storage_bytes(), 512 + 64 + 4);
    }

    #[test]
    fn error_bounded_by_block_amax() {
        let x = randn(512, 3, 3.0);
        let fq = fake_quant(&x, Rounding::Rtn, None);
        for (blk, dblk) in x.chunks(16).zip(fq.chunks(16)) {
            let amax_b = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = amax_b / 6.0 * (1.0 + 0.125) + 1e-7;
            for (a, b) in blk.iter().zip(dblk) {
                assert!((a - b).abs() <= bound, "err {} bound {}", (a - b).abs(), bound);
            }
        }
    }

    #[test]
    fn zero_tensor() {
        let x = vec![0.0f32; 64];
        assert!(fake_quant(&x, Rounding::Rtn, None).iter().all(|&v| v == 0.0));
        assert_eq!(ftz_ratio(&x), 0.0);
    }

    #[test]
    fn outlier_flushes_block_neighbours() {
        let mut x = vec![0.01f32; 64];
        x[5] = 1000.0;
        let d = fake_quant(&x, Rounding::Rtn, None);
        assert!(d[0] == 0.0 && d[1] == 0.0, "small block-0 values flushed");
        assert!((d[5] - 1000.0).abs() / 1000.0 < 0.07);
        // other blocks keep their values
        assert!((d[20] - 0.01).abs() / 0.01 < 0.25);
        assert!(ftz_ratio(&x) > 0.0);
    }

    #[test]
    fn sr_unbiased_pipeline() {
        let x = randn(64, 4, 1.0);
        let mut rng = Rng::new(5);
        let n = 2000;
        let mut acc = vec![0.0f64; 64];
        for _ in 0..n {
            let d = fake_quant(&x, Rounding::Sr, Some(&mut rng));
            for (a, &v) in acc.iter_mut().zip(&d) {
                *a += v as f64;
            }
        }
        for (i, (&a, &v)) in acc.iter().zip(&x).enumerate() {
            let mean = a / n as f64;
            let blk = &x[(i / 16) * 16..(i / 16 + 1) * 16];
            let amax_b = blk.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
            assert!(
                (mean - v as f64).abs() < (amax_b / 6.0) as f64 + 0.02,
                "bias at {i}: {mean} vs {v}"
            );
        }
    }

    #[test]
    fn fake_quant_2d_tile1_equals_1d() {
        let x = Mat::from_vec(8, 32, randn(256, 6, 1.0));
        let a = fake_quant_mat(&x);
        let b = fake_quant_mat_2d(&x, 1);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn fake_quant_2d_not_finer_than_1d() {
        let x = Mat::from_vec(64, 64, randn(4096, 7, 2.0));
        let e1 = x.mse(&fake_quant_mat(&x));
        let e2 = x.mse(&fake_quant_mat_2d(&x, 16));
        assert!(e2 >= e1 * 0.999, "2D {e2} vs 1D {e1}");
    }

    #[test]
    fn mse_scales_quadratically() {
        let x = randn(1024, 8, 1.0);
        let x10: Vec<f32> = x.iter().map(|&v| v * 10.0).collect();
        let m1 = quant_mse(&x);
        let m2 = quant_mse(&x10);
        assert!((m2 / m1 - 100.0).abs() < 7.0, "ratio {}", m2 / m1);
    }
}
