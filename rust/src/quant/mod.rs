//! NVFP4 / MXFP4 / FP8 numeric-format substrate.
//!
//! Everything the paper's quantization pipeline needs, natively in Rust so
//! the coordinator can run diagnostics, HCP selection and format benches
//! without touching Python: E2M1 + E4M3 codecs, two-level microscaling
//! (App. C.4), stochastic rounding, the MXFP4 baseline and the randomized
//! Hadamard transform.

pub mod e2m1;
pub mod e4m3;
pub mod mxfp4;
pub mod nvfp4;
pub mod recover;
pub mod rht;

/// Per-tensor FP8 (e4m3) fake quantization — the FP8 baseline runs.
pub fn fp8_fake_quant(x: &[f32]) -> Vec<f32> {
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return x.to_vec();
    }
    let s = e4m3::E4M3_MAX / amax;
    x.iter().map(|&v| e4m3::rtn(v * s) / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn fp8_much_finer_than_fp4() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let d8 = fp8_fake_quant(&x);
        let mse8: f64 = x
            .iter()
            .zip(&d8)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / x.len() as f64;
        assert!(mse8 < nvfp4::quant_mse(&x) / 10.0);
    }
}
