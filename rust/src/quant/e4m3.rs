//! FP8 E4M3 codec (fn variant: no inf, max ±448), used for NVFP4 block
//! decode scales (App. C.4 Eq. 41).
//!
//! `rtn` mirrors ref.py's frexp-based f32 emulation bit-for-bit; the
//! encode/decode pair additionally gives the real 8-bit storage format
//! (sign 1, exp 4 bias 7, mant 3) for the packed representation.

pub const E4M3_MAX: f32 = 448.0;
const MIN_NORMAL_EXP: i32 = -6;
const MANT_BITS: i32 = 3;

/// floor(log2(|v|)) for positive finite v, exact (via f32 bits + subnormal
/// normalization) — the Rust analogue of jnp.frexp's exponent.
#[inline]
fn floor_log2(a: f32) -> i32 {
    debug_assert!(a > 0.0);
    let bits = a.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp != 0 {
        exp - 127
    } else {
        // f32 subnormal: a = mant * 2^-149, floor(log2 mant) = 31 - lz
        let mant = bits & 0x7F_FFFF;
        -149 + (31 - mant.leading_zeros() as i32)
    }
}

/// Round half to even on the integer lattice.
#[inline]
fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) & 1 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Round-to-nearest-even onto the E4M3 lattice, saturating at ±448.
pub fn rtn(v: f32) -> f32 {
    if v == 0.0 {
        return 0.0;
    }
    let a = v.abs();
    let s = if v < 0.0 { -1.0 } else { 1.0 };
    let e = floor_log2(a).max(MIN_NORMAL_EXP);
    let step = (2.0f32).powi(e - MANT_BITS);
    let r = (round_half_even(a / step) * step).min(E4M3_MAX);
    s * r
}

/// Encode an f32 (rounding to the lattice first) into the 8-bit format.
pub fn encode(v: f32) -> u8 {
    let q = rtn(v);
    if q == 0.0 {
        return 0;
    }
    let sign = if q < 0.0 { 0x80u8 } else { 0 };
    let a = q.abs();
    let e = floor_log2(a);
    if e < MIN_NORMAL_EXP {
        // subnormal: exp field 0, mantissa in units of 2^-9
        let mant = (a / (2.0f32).powi(MIN_NORMAL_EXP - MANT_BITS)).round() as u8;
        return sign | (mant & 0x07);
    }
    let exp_field = (e + 7) as u8;
    let mant = ((a / (2.0f32).powi(e) - 1.0) * 8.0).round() as u8;
    sign | (exp_field << 3) | (mant & 0x07)
}

/// Decode the 8-bit format to f32.
pub fn decode(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0 } else { 1.0 };
    let exp_field = ((code >> 3) & 0x0F) as i32;
    let mant = (code & 0x07) as f32;
    if exp_field == 0 {
        return sign * mant * (2.0f32).powi(MIN_NORMAL_EXP - MANT_BITS);
    }
    sign * (1.0 + mant / 8.0) * (2.0f32).powi(exp_field - 7)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturates_at_max() {
        assert_eq!(rtn(448.0), 448.0);
        assert_eq!(rtn(1e9), 448.0);
        assert_eq!(rtn(-1e9), -448.0);
    }

    #[test]
    fn known_values() {
        assert_eq!(rtn(0.0), 0.0);
        assert_eq!(rtn(1.0), 1.0);
        assert_eq!(rtn(17.3), 18.0); // step 2 at exponent 4
        assert_eq!(rtn(-17.3), -18.0);
        assert_eq!(rtn(447.0), 448.0); // step 32 at exponent 8
    }

    #[test]
    fn encode_decode_roundtrip_lattice() {
        // every normal lattice point must roundtrip exactly
        for exp in -6..=8i32 {
            for m in 0..8u32 {
                let v = (1.0 + m as f32 / 8.0) * (2.0f32).powi(exp);
                if v > 448.0 {
                    continue;
                }
                assert_eq!(decode(encode(v)), v, "v={v}");
                assert_eq!(decode(encode(-v)), -v);
            }
        }
        // subnormals
        for m in 1..8u32 {
            let v = m as f32 * (2.0f32).powi(-9);
            assert_eq!(decode(encode(v)), v);
        }
    }

    #[test]
    fn rtn_idempotent() {
        let mut state = 99u64;
        for _ in 0..2000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let u = ((state >> 40) as f32) / (1u64 << 24) as f32;
            let v = (u - 0.5) * 1000.0;
            let q = rtn(v);
            assert_eq!(rtn(q), q, "not idempotent at {v}");
            assert_eq!(decode(encode(q)), q, "codec mismatch at {v} -> {q}");
        }
    }

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(0.99999), -1);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(448.0), 8);
        assert_eq!(floor_log2(0.015625), -6);
    }
}
